//! Device Measurements (paper Fig 1 / §III-D, offline component).
//!
//! Sweeps every valid system configuration `<ce, N_threads, g>` for every
//! model variant on a target device, collects latency statistics (min / max
//! / avg / median / n-th percentile) and peak memory, and organises the
//! results into look-up tables (LUTs).  The System Optimisation module then
//! performs a complete enumerative search over these LUTs, and the Runtime
//! Manager keeps them resident for run-time re-tuning — exactly the paper's
//! two consumers.
//!
//! Sampling: each configuration is "run" `runs` times (default 200 with 15
//! warm-ups, matching §IV-A) by drawing from the perf model with
//! deterministic log-normal noise.  With `MeasureMode::HostCalibrated`, the
//! CPU-engine base latency is replaced by real PJRT host wall-clock
//! measurements of the actual artifact, keeping the LUT anchored to real
//! executions where the testbed has real hardware (the host CPU).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::device::{DeviceProfile, EngineKind};
use crate::dvfs::Governor;
use crate::model::Registry;
use crate::perf::{self, ExecConditions};
use crate::runtime::Backend;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;
use crate::util::stats::LatencyStats;

/// Default measured runs per configuration (paper §IV-A: 200 runs).
pub const DEFAULT_RUNS: usize = 200;
/// Default discarded warm-up runs per configuration (paper §IV-A: 15).
pub const DEFAULT_WARMUP: usize = 15;

/// How device measurements are produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeasureMode {
    /// Pure performance-model sampling (deterministic; default).
    Model,
    /// CPU-engine entries calibrated by really executing the artifact on
    /// the host PJRT client; other engines remain model-driven.
    HostCalibrated,
}

/// One measured system configuration of a variant on a device.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LutKey {
    /// Variant name (`<family>__<precision>__b1`).
    pub variant: String,
    /// Engine the configuration runs on.
    pub engine: EngineKind,
    /// CPU threads (1 for offload engines).
    pub threads: usize,
    /// DVFS governor in effect.
    pub governor: Governor,
}

impl LutKey {
    /// `variant|engine|threads|governor` — the saved-LUT key format.
    pub fn id(&self) -> String {
        format!("{}|{}|{}|{}", self.variant, self.engine.name(), self.threads,
                self.governor.name())
    }

    /// Parse a [`LutKey::id`] string.
    pub fn parse(id: &str) -> Result<Self> {
        let parts: Vec<&str> = id.split('|').collect();
        if parts.len() != 4 {
            anyhow::bail!("bad LUT key `{id}`");
        }
        Ok(LutKey {
            variant: parts[0].to_string(),
            engine: EngineKind::parse(parts[1])?,
            threads: parts[2].parse().context("threads")?,
            governor: Governor::parse(parts[3])?,
        })
    }
}

/// Measured statistics for one configuration.
#[derive(Debug, Clone)]
pub struct LutEntry {
    /// Latency summary over the measured runs (ms).
    pub latency: LatencyStats,
    /// Peak working-set bytes (weights + DLACL buffers).
    pub mem_bytes: u64,
    /// Accuracy of the variant (copied from the manifest for locality:
    /// the Runtime Manager keeps only the LUT at run time, §III-D).
    pub accuracy: f64,
}

/// The device-specific look-up table.
#[derive(Debug, Clone)]
pub struct Lut {
    /// Device the measurements were taken on.
    pub device: String,
    /// Measured configurations.
    pub entries: BTreeMap<LutKey, LutEntry>,
}

impl Lut {
    /// The entry for one configuration, if measured.
    pub fn get(&self, key: &LutKey) -> Option<&LutEntry> {
        self.entries.get(key)
    }

    /// A copy with every latency statistic of `engine`'s entries
    /// multiplied by `factor` (accuracy and memory untouched, other
    /// engines byte-identical) — the LUT-side of a per-engine online
    /// correction, paired with
    /// [`crate::designspace::LutDelta::engine_scale`] so frontier caches
    /// can follow the change incrementally.
    pub fn scaled_engine(&self, engine: EngineKind, factor: f64) -> Lut {
        let mut entries = self.entries.clone();
        for (k, e) in entries.iter_mut() {
            if k.engine == engine {
                e.latency = e.latency.scaled(factor);
            }
        }
        Lut { device: self.device.clone(), entries }
    }

    /// Number of measured configurations.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing was measured.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All keys for a given variant (the optimizer's system dimension).
    pub fn keys_for_variant<'a>(&'a self, variant: &'a str)
                                -> impl Iterator<Item = &'a LutKey> {
        self.entries.keys().filter(move |k| k.variant == variant)
    }

    // -- serialization ----------------------------------------------------

    /// Serialise for `--out lut.json`.
    pub fn to_json(&self) -> Value {
        let entries: Vec<Value> = self
            .entries
            .iter()
            .map(|(k, e)| {
                json::obj(vec![
                    ("key", json::s(&k.id())),
                    ("latency", e.latency.to_json()),
                    ("mem_bytes", json::num(e.mem_bytes as f64)),
                    ("accuracy", json::num(e.accuracy)),
                ])
            })
            .collect();
        json::obj(vec![
            ("device", json::s(&self.device)),
            ("entries", Value::Arr(entries)),
        ])
    }

    /// Parse the [`Lut::to_json`] representation.
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for e in v.req("entries")?.as_arr()? {
            let key = LutKey::parse(e.req("key")?.as_str()?)?;
            entries.insert(key, LutEntry {
                latency: LatencyStats::from_json(e.req("latency")?)?,
                mem_bytes: e.req("mem_bytes")?.as_u64()?,
                accuracy: e.req("accuracy")?.as_f64()?,
            });
        }
        Ok(Lut { device: v.req("device")?.as_str()?.to_string(), entries })
    }

    /// Write the JSON representation to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), json::to_string(&self.to_json()))
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }

    /// Read a LUT previously written by [`Lut::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_json(&json::parse(&text)?)
    }
}

/// The Device Measurements module.
pub struct Measurer<'a> {
    /// Device being measured.
    pub device: &'a DeviceProfile,
    /// Model space to sweep.
    pub registry: &'a Registry,
    /// Measured runs per configuration.
    pub runs: usize,
    /// Discarded warm-up runs per configuration.
    pub warmup: usize,
    /// Log-normal sigma of run-to-run jitter.
    pub noise_sigma: f64,
    /// Model-driven or host-calibrated measurement.
    pub mode: MeasureMode,
    /// Required for `HostCalibrated`: any execution backend (PJRT or sim).
    pub runtime: Option<&'a dyn Backend>,
}

impl<'a> Measurer<'a> {
    /// A measurer with the paper's default protocol.
    pub fn new(device: &'a DeviceProfile, registry: &'a Registry) -> Self {
        Measurer {
            device,
            registry,
            runs: DEFAULT_RUNS,
            warmup: DEFAULT_WARMUP,
            noise_sigma: 0.04,
            mode: MeasureMode::Model,
            runtime: None,
        }
    }

    /// Override the measurement depth (tests/smoke use shallow sweeps).
    pub fn with_runs(mut self, runs: usize, warmup: usize) -> Self {
        self.runs = runs;
        self.warmup = warmup;
        self
    }

    /// Override the run-to-run jitter; 0 collapses every sample to the
    /// performance model's closed-form prediction (the golden-pinned
    /// `opt-bench --smoke` path).
    pub fn with_noise_sigma(mut self, sigma: f64) -> Self {
        self.noise_sigma = sigma;
        self
    }

    /// Calibrate CPU entries against real executions on `rt`.
    pub fn host_calibrated(mut self, rt: &'a dyn Backend) -> Self {
        self.mode = MeasureMode::HostCalibrated;
        self.runtime = Some(rt);
        self
    }

    /// Thread counts valid for an engine (offload engines take one entry).
    fn threads_for(&self, kind: EngineKind) -> Vec<usize> {
        match kind {
            EngineKind::Cpu => self.device.thread_candidates(),
            _ => vec![1],
        }
    }

    /// Sweep every valid configuration of every batch-1 variant.
    pub fn measure_all(&self) -> Result<Lut> {
        let mut entries = BTreeMap::new();
        for v in self.registry.variants().iter().filter(|v| v.batch == 1) {
            for spec in &self.device.engines {
                for &threads in &self.threads_for(spec.kind) {
                    for &governor in &self.device.governors {
                        let key = LutKey {
                            variant: v.name.clone(),
                            engine: spec.kind,
                            threads,
                            governor,
                        };
                        let entry = self.measure_one(&key)?;
                        entries.insert(key, entry);
                    }
                }
            }
        }
        Ok(Lut { device: self.device.name.to_string(), entries })
    }

    /// Measure a single configuration: warm-ups discarded, `runs` samples
    /// summarised (the paper's 200-run protocol).
    pub fn measure_one(&self, key: &LutKey) -> Result<LutEntry> {
        let v = self
            .registry
            .get(&key.variant)
            .ok_or_else(|| anyhow!("unknown variant `{}`", key.variant))?;
        let cond = ExecConditions {
            governor: key.governor,
            threads: key.threads,
            load_factor: 0.0,
            thermal_freq_scale: 1.0,
        };
        let base = perf::latency_ms(self.device, key.engine, v, &cond)
            .ok_or_else(|| anyhow!("device {} has no engine {}",
                                   self.device.name, key.engine.name()))?;

        let base = match (self.mode, key.engine) {
            (MeasureMode::HostCalibrated, EngineKind::Cpu) => {
                self.host_latency_ms(v)?.unwrap_or(base)
            }
            _ => base,
        };

        // Deterministic per-key noise stream.
        let mut rng = Rng::new(seed_for(self.device.name, &key.id()));
        let mut samples = Vec::with_capacity(self.runs);
        for i in 0..(self.warmup + self.runs) {
            // Warm-up runs are slower (cold caches / lazy driver init).
            let cold = if i < self.warmup { 1.5 } else { 1.0 };
            let s = base * cold * rng.lognormal(self.noise_sigma);
            if i >= self.warmup {
                samples.push(s);
            }
        }
        Ok(LutEntry {
            latency: LatencyStats::from_samples(&samples),
            mem_bytes: v.mem_bytes(),
            accuracy: v.accuracy,
        })
    }

    /// Median real host latency through the backend (few runs; used as the
    /// CPU calibration anchor).  `None` when the backend has no artifact
    /// for this variant (PJRT before `make artifacts`) — the model
    /// prediction then stands in.  A load failure on an artifact that
    /// exists (corrupt HLO) is a real error and propagates.
    fn host_latency_ms(&self, v: &crate::model::ModelVariant)
                       -> Result<Option<f64>> {
        let Some(rt) = self.runtime else { return Ok(None) };
        let path = self.registry.hlo_path(v);
        if let Err(e) = rt.load(&v.name, &path) {
            if path.exists() {
                return Err(e.context(format!(
                    "host calibration: loading artifact for `{}`", v.name
                )));
            }
            return Ok(None);
        }
        let input = vec![0.1f32; v.input_elems()];
        let mut times = Vec::new();
        for _ in 0..5 {
            let out = rt.execute(&v.name, input.clone(), &v.input_shape)?;
            times.push(out.host_ms);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(Some(times[times.len() / 2]))
    }
}

fn seed_for(device: &str, key_id: &str) -> u64 {
    // FNV-1a over device + key for stable per-configuration seeds.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in device.bytes().chain(key_id.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::{samsung_a71, sony_c5};
    use crate::model::test_fixtures::fake_registry;

    #[test]
    fn sweep_covers_full_config_space() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(20, 2).measure_all().unwrap();
        // 12 variants x (cpu:4 threads + gpu:1 + npu:1 = 6 engine-thread
        // combos) x 3 governors
        assert_eq!(lut.len(), 12 * 6 * 3);
    }

    #[test]
    fn sony_has_no_npu_entries() {
        let dev = sony_c5();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        assert!(lut.entries.keys().all(|k| k.engine != EngineKind::Npu));
        // cpu:4 thread counts + gpu:1, 2 governors
        assert_eq!(lut.len(), 12 * 5 * 2);
    }

    #[test]
    fn measurements_are_deterministic() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let m = Measurer::new(&dev, &reg).with_runs(30, 3);
        let key = LutKey {
            variant: "mobilenet_v2_100__int8__b1".into(),
            engine: EngineKind::Npu,
            threads: 1,
            governor: Governor::Performance,
        };
        let a = m.measure_one(&key).unwrap();
        let b = m.measure_one(&key).unwrap();
        assert_eq!(a.latency, b.latency);
    }

    #[test]
    fn stats_are_ordered() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let m = Measurer::new(&dev, &reg).with_runs(100, 5);
        let key = LutKey {
            variant: "inception_v3__fp32__b1".into(),
            engine: EngineKind::Gpu,
            threads: 1,
            governor: Governor::Schedutil,
        };
        let e = m.measure_one(&key).unwrap();
        let l = &e.latency;
        assert!(l.min <= l.median && l.median <= l.p90);
        assert!(l.p90 <= l.p99 && l.p99 <= l.max);
        assert_eq!(l.n, 100);
    }

    #[test]
    fn lut_key_id_roundtrip() {
        let key = LutKey {
            variant: "deeplab_v3__fp16__b1".into(),
            engine: EngineKind::Npu,
            threads: 4,
            governor: Governor::EnergyStep,
        };
        assert_eq!(LutKey::parse(&key.id()).unwrap(), key);
        assert!(LutKey::parse("a|b").is_err());
    }

    #[test]
    fn lut_json_roundtrip() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let back = Lut::from_json(&lut.to_json()).unwrap();
        assert_eq!(back.device, lut.device);
        assert_eq!(back.len(), lut.len());
        for (k, e) in &lut.entries {
            let b = back.get(k).unwrap();
            assert_eq!(b.latency, e.latency);
            assert_eq!(b.mem_bytes, e.mem_bytes);
        }
    }

    #[test]
    fn host_calibrated_against_sim_backend() {
        // Hermetic calibration: the CPU anchor comes from SimBackend
        // executions instead of real PJRT runs.
        let dev = samsung_a71();
        let reg = fake_registry();
        let be = crate::runtime::SimBackend::new(dev.clone(), reg.clone());
        let lut = Measurer::new(&dev, &reg)
            .with_runs(10, 1)
            .host_calibrated(&be)
            .measure_all()
            .unwrap();
        assert_eq!(lut.len(), 12 * 6 * 3);
        assert!(lut.entries.values().all(|e| e.latency.avg > 0.0));
    }

    #[test]
    fn unknown_variant_rejected() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let m = Measurer::new(&dev, &reg);
        let key = LutKey {
            variant: "ghost__fp32__b1".into(),
            engine: EngineKind::Cpu,
            threads: 1,
            governor: Governor::Performance,
        };
        assert!(m.measure_one(&key).is_err());
    }

    #[test]
    fn keys_for_variant_filters() {
        let dev = sony_c5();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(5, 0).measure_all().unwrap();
        let n = lut.keys_for_variant("mobilenet_v2_100__fp32__b1").count();
        assert_eq!(n, 5 * 2); // 5 engine-thread combos x 2 governors
    }
}
