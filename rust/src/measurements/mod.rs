//! Device Measurements (paper Fig 1 / §III-D, offline component).
//!
//! Sweeps every valid system configuration `<ce, N_threads, g>` for every
//! model variant on a target device, collects latency statistics (min / max
//! / avg / median / n-th percentile) and peak memory, and organises the
//! results into look-up tables (LUTs).  The System Optimisation module then
//! performs a complete enumerative search over these LUTs, and the Runtime
//! Manager keeps them resident for run-time re-tuning — exactly the paper's
//! two consumers.
//!
//! Sampling: each configuration is "run" `runs` times (default 200 with 15
//! warm-ups, matching §IV-A) by drawing from the perf model with
//! deterministic log-normal noise.  With `MeasureMode::HostCalibrated`, the
//! CPU-engine base latency is replaced by real PJRT host wall-clock
//! measurements of the actual artifact, keeping the LUT anchored to real
//! executions where the testbed has real hardware (the host CPU).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, ensure, Context, Result};

use crate::device::{DeviceProfile, EngineKind};
use crate::dvfs::Governor;
use crate::model::Registry;
use crate::perf::{self, ExecConditions, StageCost};
use crate::runtime::Backend;
use crate::util::json::{self, Value};
use crate::util::rng::Rng;
use crate::util::stats::LatencyStats;

/// Default measured runs per configuration (paper §IV-A: 200 runs).
pub const DEFAULT_RUNS: usize = 200;
/// Default discarded warm-up runs per configuration (paper §IV-A: 15).
pub const DEFAULT_WARMUP: usize = 15;

/// How device measurements are produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MeasureMode {
    /// Pure performance-model sampling (deterministic; default).
    Model,
    /// CPU-engine entries calibrated by really executing the artifact on
    /// the host PJRT client; other engines remain model-driven.
    HostCalibrated,
}

/// A partitioned execution plan: ordered per-segment engine assignments
/// plus the interior cut points (per-mille of the variant's FLOPs/bytes,
/// strictly increasing, exclusive of 0 and 1000).  Segment i runs on
/// `engines[i]` and covers `(cuts[i-1], cuts[i]]`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PartitionPlan {
    /// Engine per segment, in pipeline order (all distinct).
    pub engines: Vec<EngineKind>,
    /// Interior cut points, per-mille (len = engines.len() - 1).
    pub cuts_pm: Vec<u32>,
}

impl PartitionPlan {
    /// `cpu>gpu@500` / `gpu>cpu>nnapi@250+750` — the saved-key encoding,
    /// carried in the engine slot of [`LutKey::id`].
    pub fn id(&self) -> String {
        let engines: Vec<&str> =
            self.engines.iter().map(|e| e.name()).collect();
        let cuts: Vec<String> =
            self.cuts_pm.iter().map(|c| c.to_string()).collect();
        format!("{}@{}", engines.join(">"), cuts.join("+"))
    }

    /// Parse a [`PartitionPlan::id`] string.
    pub fn parse(s: &str) -> Result<Self> {
        let (es, cs) = s
            .split_once('@')
            .ok_or_else(|| anyhow!("bad partition plan `{s}`"))?;
        let engines = es
            .split('>')
            .map(EngineKind::parse)
            .collect::<Result<Vec<_>>>()?;
        let cuts_pm = cs
            .split('+')
            .map(|c| c.parse::<u32>().context("cut point"))
            .collect::<Result<Vec<_>>>()?;
        ensure!(engines.len() >= 2 && cuts_pm.len() == engines.len() - 1,
                "bad partition plan `{s}`: need n engines, n-1 cuts");
        ensure!(cuts_pm.iter().all(|&c| c > 0 && c < 1000)
                    && cuts_pm.windows(2).all(|w| w[0] < w[1]),
                "bad partition plan `{s}`: cuts must be strictly \
                 increasing in (0, 1000)");
        Ok(PartitionPlan { engines, cuts_pm })
    }
}

/// How a configuration executes: the whole model on one engine, or split
/// into pipelined segments across several.  `Mono` sorts first so a LUT
/// without partitioned entries keeps its historical BTreeMap order.
#[derive(Debug, Clone, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ExecPlan {
    /// Whole model on `LutKey::engine` (the historical design space).
    #[default]
    Mono,
    /// Pipelined multi-engine partition.
    Split(PartitionPlan),
}

impl ExecPlan {
    /// The engines this plan occupies, given the key's (first-stage)
    /// engine for the monolithic case.
    pub fn engines(&self, mono_engine: EngineKind) -> Vec<EngineKind> {
        match self {
            ExecPlan::Mono => vec![mono_engine],
            ExecPlan::Split(p) => p.engines.clone(),
        }
    }

    /// True for partitioned plans.
    pub fn is_split(&self) -> bool {
        matches!(self, ExecPlan::Split(_))
    }
}

/// The default partition grid for a device: every ordered pair of
/// distinct available engines at cuts {250, 500, 750}, plus every
/// ordered triple of distinct engines at cuts (250, 750).  On a
/// 3-engine device that is 24 plans per variant; a 2-engine device gets
/// the 6 pair plans only.
pub fn partition_plans(dev: &DeviceProfile) -> Vec<PartitionPlan> {
    let avail: Vec<EngineKind> = dev.engines.iter().map(|s| s.kind).collect();
    let mut plans = Vec::new();
    for &a in &avail {
        for &b in &avail {
            if a == b {
                continue;
            }
            for &cut in &[250u32, 500, 750] {
                plans.push(PartitionPlan {
                    engines: vec![a, b],
                    cuts_pm: vec![cut],
                });
            }
        }
    }
    for &a in &avail {
        for &b in &avail {
            for &c in &avail {
                if a == b || a == c || b == c {
                    continue;
                }
                plans.push(PartitionPlan {
                    engines: vec![a, b, c],
                    cuts_pm: vec![250, 750],
                });
            }
        }
    }
    plans
}

/// One measured system configuration of a variant on a device.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LutKey {
    /// Variant name (`<family>__<precision>__b1`).
    pub variant: String,
    /// Engine the configuration runs on (first-stage engine for
    /// partitioned plans).
    pub engine: EngineKind,
    /// CPU threads (1 for offload engines).
    pub threads: usize,
    /// DVFS governor in effect.
    pub governor: Governor,
    /// Monolithic or partitioned execution.  Last field so the derived
    /// `Ord` keeps all-mono LUTs in the historical order.
    pub plan: ExecPlan,
}

impl LutKey {
    /// `variant|engine|threads|governor` — the saved-LUT key format.
    /// Partitioned keys carry the plan in the engine slot
    /// (`variant|cpu>gpu@500|threads|governor`).
    pub fn id(&self) -> String {
        let engine = match &self.plan {
            ExecPlan::Mono => self.engine.name().to_string(),
            ExecPlan::Split(p) => p.id(),
        };
        format!("{}|{}|{}|{}", self.variant, engine, self.threads,
                self.governor.name())
    }

    /// Parse a [`LutKey::id`] string.
    pub fn parse(id: &str) -> Result<Self> {
        let parts: Vec<&str> = id.split('|').collect();
        if parts.len() != 4 {
            anyhow::bail!("bad LUT key `{id}`");
        }
        let (engine, plan) = if parts[1].contains('>') {
            let p = PartitionPlan::parse(parts[1])?;
            (p.engines[0], ExecPlan::Split(p))
        } else {
            (EngineKind::parse(parts[1])?, ExecPlan::Mono)
        };
        Ok(LutKey {
            variant: parts[0].to_string(),
            engine,
            threads: parts[2].parse().context("threads")?,
            governor: Governor::parse(parts[3])?,
            plan,
        })
    }
}

/// Measured statistics for one configuration.
#[derive(Debug, Clone)]
pub struct LutEntry {
    /// Latency summary over the measured runs (ms).
    pub latency: LatencyStats,
    /// Peak working-set bytes (weights + DLACL buffers; plus boundary
    /// activation double-buffers for partitioned plans).
    pub mem_bytes: u64,
    /// Accuracy of the variant (copied from the manifest for locality:
    /// the Runtime Manager keeps only the LUT at run time, §III-D).
    pub accuracy: f64,
    /// Per-stage roofline breakdown for partitioned plans (empty for
    /// monolithic entries) — the condition-adjustment model re-finds the
    /// pipeline bottleneck from these under per-engine load/thermal.
    pub stages: Vec<StageCost>,
}

/// The device-specific look-up table.
#[derive(Debug, Clone)]
pub struct Lut {
    /// Device the measurements were taken on.
    pub device: String,
    /// Measured configurations.
    pub entries: BTreeMap<LutKey, LutEntry>,
}

impl Lut {
    /// The entry for one configuration, if measured.
    pub fn get(&self, key: &LutKey) -> Option<&LutEntry> {
        self.entries.get(key)
    }

    /// A copy with every latency statistic of `engine`'s entries
    /// multiplied by `factor` (accuracy and memory untouched, other
    /// engines byte-identical) — the LUT-side of a per-engine online
    /// correction, paired with
    /// [`crate::designspace::LutDelta::engine_scale`] so frontier caches
    /// can follow the change incrementally.
    pub fn scaled_engine(&self, engine: EngineKind, factor: f64) -> Lut {
        let mut entries = self.entries.clone();
        for (k, e) in entries.iter_mut() {
            if k.engine == engine {
                e.latency = e.latency.scaled(factor);
                // Partitioned entries (keyed by their first-stage engine)
                // scale their stage breakdown uniformly so the stored
                // stats/stages ratio — and thus the condition-adjustment
                // factor — stays consistent.
                for st in e.stages.iter_mut() {
                    st.stage_ms *= factor;
                    st.xfer_ms *= factor;
                }
            }
        }
        Lut { device: self.device.clone(), entries }
    }

    /// Number of measured configurations.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing was measured.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// All keys for a given variant (the optimizer's system dimension).
    pub fn keys_for_variant<'a>(&'a self, variant: &'a str)
                                -> impl Iterator<Item = &'a LutKey> {
        self.entries.keys().filter(move |k| k.variant == variant)
    }

    // -- serialization ----------------------------------------------------

    /// Serialise for `--out lut.json`.  Monolithic entries keep the
    /// historical four-field shape; partitioned entries append their
    /// stage breakdown.
    pub fn to_json(&self) -> Value {
        let entries: Vec<Value> = self
            .entries
            .iter()
            .map(|(k, e)| {
                let mut fields = vec![
                    ("key", json::s(&k.id())),
                    ("latency", e.latency.to_json()),
                    ("mem_bytes", json::num(e.mem_bytes as f64)),
                    ("accuracy", json::num(e.accuracy)),
                ];
                if !e.stages.is_empty() {
                    let stages: Vec<Value> = e
                        .stages
                        .iter()
                        .map(|st| json::obj(vec![
                            ("engine", json::s(st.engine.name())),
                            ("stage_ms", json::num(st.stage_ms)),
                            ("xfer_ms", json::num(st.xfer_ms)),
                        ]))
                        .collect();
                    fields.push(("stages", Value::Arr(stages)));
                }
                json::obj(fields)
            })
            .collect();
        json::obj(vec![
            ("device", json::s(&self.device)),
            ("entries", Value::Arr(entries)),
        ])
    }

    /// Parse the [`Lut::to_json`] representation.
    pub fn from_json(v: &Value) -> Result<Self> {
        let mut entries = BTreeMap::new();
        for e in v.req("entries")?.as_arr()? {
            let key = LutKey::parse(e.req("key")?.as_str()?)?;
            let stages = match e.get("stages") {
                None => Vec::new(),
                Some(arr) => arr
                    .as_arr()?
                    .iter()
                    .map(|st| {
                        Ok(StageCost {
                            engine: EngineKind::parse(
                                st.req("engine")?.as_str()?)?,
                            stage_ms: st.req("stage_ms")?.as_f64()?,
                            xfer_ms: st.req("xfer_ms")?.as_f64()?,
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
            };
            entries.insert(key, LutEntry {
                latency: LatencyStats::from_json(e.req("latency")?)?,
                mem_bytes: e.req("mem_bytes")?.as_u64()?,
                accuracy: e.req("accuracy")?.as_f64()?,
                stages,
            });
        }
        Ok(Lut { device: v.req("device")?.as_str()?.to_string(), entries })
    }

    /// Write the JSON representation to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), json::to_string(&self.to_json()))
            .with_context(|| format!("writing {}", path.as_ref().display()))
    }

    /// Read a LUT previously written by [`Lut::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_json(&json::parse(&text)?)
    }
}

/// The Device Measurements module.
pub struct Measurer<'a> {
    /// Device being measured.
    pub device: &'a DeviceProfile,
    /// Model space to sweep.
    pub registry: &'a Registry,
    /// Measured runs per configuration.
    pub runs: usize,
    /// Discarded warm-up runs per configuration.
    pub warmup: usize,
    /// Log-normal sigma of run-to-run jitter.
    pub noise_sigma: f64,
    /// Model-driven or host-calibrated measurement.
    pub mode: MeasureMode,
    /// Required for `HostCalibrated`: any execution backend (PJRT or sim).
    pub runtime: Option<&'a dyn Backend>,
}

impl<'a> Measurer<'a> {
    /// A measurer with the paper's default protocol.
    pub fn new(device: &'a DeviceProfile, registry: &'a Registry) -> Self {
        Measurer {
            device,
            registry,
            runs: DEFAULT_RUNS,
            warmup: DEFAULT_WARMUP,
            noise_sigma: 0.04,
            mode: MeasureMode::Model,
            runtime: None,
        }
    }

    /// Override the measurement depth (tests/smoke use shallow sweeps).
    pub fn with_runs(mut self, runs: usize, warmup: usize) -> Self {
        self.runs = runs;
        self.warmup = warmup;
        self
    }

    /// Override the run-to-run jitter; 0 collapses every sample to the
    /// performance model's closed-form prediction (the golden-pinned
    /// `opt-bench --smoke` path).
    pub fn with_noise_sigma(mut self, sigma: f64) -> Self {
        self.noise_sigma = sigma;
        self
    }

    /// Calibrate CPU entries against real executions on `rt`.
    pub fn host_calibrated(mut self, rt: &'a dyn Backend) -> Self {
        self.mode = MeasureMode::HostCalibrated;
        self.runtime = Some(rt);
        self
    }

    /// Thread counts valid for an engine (offload engines take one entry).
    fn threads_for(&self, kind: EngineKind) -> Vec<usize> {
        match kind {
            EngineKind::Cpu => self.device.thread_candidates(),
            _ => vec![1],
        }
    }

    /// Sweep every valid configuration of every batch-1 variant.
    pub fn measure_all(&self) -> Result<Lut> {
        let mut entries = BTreeMap::new();
        for v in self.registry.variants().iter().filter(|v| v.batch == 1) {
            for spec in &self.device.engines {
                for &threads in &self.threads_for(spec.kind) {
                    for &governor in &self.device.governors {
                        let key = LutKey {
                            variant: v.name.clone(),
                            engine: spec.kind,
                            threads,
                            governor,
                            plan: ExecPlan::Mono,
                        };
                        let entry = self.measure_one(&key)?;
                        entries.insert(key, entry);
                    }
                }
            }
        }
        Ok(Lut { device: self.device.name.to_string(), entries })
    }

    /// [`Measurer::measure_all`] plus one partitioned entry per (variant,
    /// plan) in the device's default grid ([`partition_plans`]), pinned
    /// to the performance governor (co-execution is a raw-speed play; the
    /// mono entries already cover the energy-biased governors).  Opt-in:
    /// LUTs produced by `measure_all` are byte-identical to before this
    /// extension existed.
    pub fn measure_with_partitions(&self) -> Result<Lut> {
        let mut lut = self.measure_all()?;
        for v in self.registry.variants().iter().filter(|v| v.batch == 1) {
            for plan in partition_plans(self.device) {
                let key = LutKey {
                    variant: v.name.clone(),
                    engine: plan.engines[0],
                    threads: perf::plan_threads(self.device, &plan.engines),
                    governor: Governor::Performance,
                    plan: ExecPlan::Split(plan),
                };
                let entry = self.measure_plan(&key)?;
                lut.entries.insert(key, entry);
            }
        }
        Ok(lut)
    }

    /// Measure one partitioned configuration: the closed-form pipelined
    /// bottleneck is sampled under the same warm-up/noise protocol as
    /// [`Measurer::measure_one`], and the nominal per-stage breakdown is
    /// stored alongside for condition adjustment.  Delegates to
    /// `measure_one` for monolithic keys.
    pub fn measure_plan(&self, key: &LutKey) -> Result<LutEntry> {
        let ExecPlan::Split(plan) = &key.plan else {
            return self.measure_one(key);
        };
        let v = self
            .registry
            .get(&key.variant)
            .ok_or_else(|| anyhow!("unknown variant `{}`", key.variant))?;
        let stages = perf::plan_stage_costs(self.device, v, &plan.engines,
                                            &plan.cuts_pm, key.governor)
            .ok_or_else(|| anyhow!("device {} lacks an engine of plan {}",
                                   self.device.name, plan.id()))?;
        let base = perf::pipelined_latency_ms(&stages);
        let mut rng = Rng::new(seed_for(self.device.name, &key.id()));
        let mut samples = Vec::with_capacity(self.runs);
        for i in 0..(self.warmup + self.runs) {
            let cold = if i < self.warmup { 1.5 } else { 1.0 };
            let s = base * cold * rng.lognormal(self.noise_sigma);
            if i >= self.warmup {
                samples.push(s);
            }
        }
        Ok(LutEntry {
            latency: LatencyStats::from_samples(&samples),
            mem_bytes: perf::plan_mem_bytes(v, &plan.cuts_pm),
            accuracy: v.accuracy,
            stages,
        })
    }

    /// Measure a single configuration: warm-ups discarded, `runs` samples
    /// summarised (the paper's 200-run protocol).
    pub fn measure_one(&self, key: &LutKey) -> Result<LutEntry> {
        let v = self
            .registry
            .get(&key.variant)
            .ok_or_else(|| anyhow!("unknown variant `{}`", key.variant))?;
        let cond = ExecConditions {
            governor: key.governor,
            threads: key.threads,
            load_factor: 0.0,
            thermal_freq_scale: 1.0,
        };
        let base = perf::latency_ms(self.device, key.engine, v, &cond)
            .ok_or_else(|| anyhow!("device {} has no engine {}",
                                   self.device.name, key.engine.name()))?;

        let base = match (self.mode, key.engine) {
            (MeasureMode::HostCalibrated, EngineKind::Cpu) => {
                self.host_latency_ms(v)?.unwrap_or(base)
            }
            _ => base,
        };

        // Deterministic per-key noise stream.
        let mut rng = Rng::new(seed_for(self.device.name, &key.id()));
        let mut samples = Vec::with_capacity(self.runs);
        for i in 0..(self.warmup + self.runs) {
            // Warm-up runs are slower (cold caches / lazy driver init).
            let cold = if i < self.warmup { 1.5 } else { 1.0 };
            let s = base * cold * rng.lognormal(self.noise_sigma);
            if i >= self.warmup {
                samples.push(s);
            }
        }
        Ok(LutEntry {
            latency: LatencyStats::from_samples(&samples),
            mem_bytes: v.mem_bytes(),
            accuracy: v.accuracy,
            stages: Vec::new(),
        })
    }

    /// Median real host latency through the backend (few runs; used as the
    /// CPU calibration anchor).  `None` when the backend has no artifact
    /// for this variant (PJRT before `make artifacts`) — the model
    /// prediction then stands in.  A load failure on an artifact that
    /// exists (corrupt HLO) is a real error and propagates.
    fn host_latency_ms(&self, v: &crate::model::ModelVariant)
                       -> Result<Option<f64>> {
        let Some(rt) = self.runtime else { return Ok(None) };
        let path = self.registry.hlo_path(v);
        if let Err(e) = rt.load(&v.name, &path) {
            if path.exists() {
                return Err(e.context(format!(
                    "host calibration: loading artifact for `{}`", v.name
                )));
            }
            return Ok(None);
        }
        let input = vec![0.1f32; v.input_elems()];
        let mut times = Vec::new();
        for _ in 0..5 {
            let out = rt.execute(&v.name, input.clone(), &v.input_shape)?;
            times.push(out.host_ms);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(Some(times[times.len() / 2]))
    }
}

/// Energy proxy of a LUT entry under `governor`: the monolithic closed
/// form on the entry's engine, or the per-stage sum (each stage billed on
/// its own engine, in pipeline order) for a partitioned entry.  `None`
/// when the device lacks one of the engines involved.
pub fn entry_energy_mj(dev: &DeviceProfile, key_engine: EngineKind,
                       entry: &LutEntry, governor: Governor) -> Option<f64> {
    if entry.stages.is_empty() {
        let spec = dev.engine(key_engine)?;
        Some(perf::energy_proxy_mj(spec, entry.latency.avg, governor))
    } else {
        let mut total = 0.0;
        for st in &entry.stages {
            let spec = dev.engine(st.engine)?;
            total += perf::energy_proxy_mj(spec, st.stage_ms, governor);
        }
        Some(total)
    }
}

fn seed_for(device: &str, key_id: &str) -> u64 {
    // FNV-1a over device + key for stable per-configuration seeds.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in device.bytes().chain(key_id.bytes()) {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::{samsung_a71, sony_c5};
    use crate::model::test_fixtures::fake_registry;

    #[test]
    fn sweep_covers_full_config_space() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(20, 2).measure_all().unwrap();
        // 12 variants x (cpu:4 threads + gpu:1 + npu:1 = 6 engine-thread
        // combos) x 3 governors
        assert_eq!(lut.len(), 12 * 6 * 3);
    }

    #[test]
    fn sony_has_no_npu_entries() {
        let dev = sony_c5();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        assert!(lut.entries.keys().all(|k| k.engine != EngineKind::Npu));
        // cpu:4 thread counts + gpu:1, 2 governors
        assert_eq!(lut.len(), 12 * 5 * 2);
    }

    #[test]
    fn measurements_are_deterministic() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let m = Measurer::new(&dev, &reg).with_runs(30, 3);
        let key = LutKey {
            variant: "mobilenet_v2_100__int8__b1".into(),
            engine: EngineKind::Npu,
            threads: 1,
            governor: Governor::Performance,
            plan: ExecPlan::Mono,
        };
        let a = m.measure_one(&key).unwrap();
        let b = m.measure_one(&key).unwrap();
        assert_eq!(a.latency, b.latency);
    }

    #[test]
    fn stats_are_ordered() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let m = Measurer::new(&dev, &reg).with_runs(100, 5);
        let key = LutKey {
            variant: "inception_v3__fp32__b1".into(),
            engine: EngineKind::Gpu,
            threads: 1,
            governor: Governor::Schedutil,
            plan: ExecPlan::Mono,
        };
        let e = m.measure_one(&key).unwrap();
        let l = &e.latency;
        assert!(l.min <= l.median && l.median <= l.p90);
        assert!(l.p90 <= l.p99 && l.p99 <= l.max);
        assert_eq!(l.n, 100);
    }

    #[test]
    fn lut_key_id_roundtrip() {
        let key = LutKey {
            variant: "deeplab_v3__fp16__b1".into(),
            engine: EngineKind::Npu,
            threads: 4,
            governor: Governor::EnergyStep,
            plan: ExecPlan::Mono,
        };
        assert_eq!(LutKey::parse(&key.id()).unwrap(), key);
        assert!(LutKey::parse("a|b").is_err());
    }

    #[test]
    fn lut_json_roundtrip() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(10, 1).measure_all().unwrap();
        let back = Lut::from_json(&lut.to_json()).unwrap();
        assert_eq!(back.device, lut.device);
        assert_eq!(back.len(), lut.len());
        for (k, e) in &lut.entries {
            let b = back.get(k).unwrap();
            assert_eq!(b.latency, e.latency);
            assert_eq!(b.mem_bytes, e.mem_bytes);
        }
    }

    #[test]
    fn host_calibrated_against_sim_backend() {
        // Hermetic calibration: the CPU anchor comes from SimBackend
        // executions instead of real PJRT runs.
        let dev = samsung_a71();
        let reg = fake_registry();
        let be = crate::runtime::SimBackend::new(dev.clone(), reg.clone());
        let lut = Measurer::new(&dev, &reg)
            .with_runs(10, 1)
            .host_calibrated(&be)
            .measure_all()
            .unwrap();
        assert_eq!(lut.len(), 12 * 6 * 3);
        assert!(lut.entries.values().all(|e| e.latency.avg > 0.0));
    }

    #[test]
    fn unknown_variant_rejected() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let m = Measurer::new(&dev, &reg);
        let key = LutKey {
            variant: "ghost__fp32__b1".into(),
            engine: EngineKind::Cpu,
            threads: 1,
            governor: Governor::Performance,
            plan: ExecPlan::Mono,
        };
        assert!(m.measure_one(&key).is_err());
    }

    #[test]
    fn keys_for_variant_filters() {
        let dev = sony_c5();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(5, 0).measure_all().unwrap();
        let n = lut.keys_for_variant("mobilenet_v2_100__fp32__b1").count();
        assert_eq!(n, 5 * 2); // 5 engine-thread combos x 2 governors
    }

    #[test]
    fn partition_grid_sizes() {
        // 3 engines: 3·2 ordered pairs × 3 cuts + 6 ordered triples.
        assert_eq!(partition_plans(&samsung_a71()).len(), 18 + 6);
        // 2 engines: 2 ordered pairs × 3 cuts, no triples.
        assert_eq!(partition_plans(&sony_c5()).len(), 6);
    }

    #[test]
    fn partition_sweep_extends_without_disturbing_mono_entries() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let m = Measurer::new(&dev, &reg).with_runs(10, 1);
        let mono = m.measure_all().unwrap();
        let full = m.measure_with_partitions().unwrap();
        // 216 mono + 12 variants × 24 plans.
        assert_eq!(full.len(), 12 * 6 * 3 + 12 * 24);
        for (k, e) in &mono.entries {
            let f = full.get(k).expect("mono key must survive");
            assert_eq!(f.latency, e.latency, "mono entry disturbed: {}",
                       k.id());
            assert!(f.stages.is_empty());
        }
        for (k, e) in &full.entries {
            if k.plan.is_split() {
                assert_eq!(k.governor, Governor::Performance);
                assert!(!e.stages.is_empty());
                assert!(e.mem_bytes
                        > reg.get(&k.variant).unwrap().mem_bytes());
            }
        }
    }

    #[test]
    fn split_key_id_roundtrip() {
        let key = LutKey {
            variant: "inception_v3__int8__b1".into(),
            engine: EngineKind::Gpu,
            threads: 8,
            governor: Governor::Performance,
            plan: ExecPlan::Split(PartitionPlan {
                engines: vec![EngineKind::Gpu, EngineKind::Npu,
                              EngineKind::Cpu],
                cuts_pm: vec![250, 750],
            }),
        };
        assert_eq!(key.id(),
                   "inception_v3__int8__b1|gpu>nnapi>cpu@250+750|8\
                    |performance");
        assert_eq!(LutKey::parse(&key.id()).unwrap(), key);
        // Malformed plans are rejected.
        assert!(LutKey::parse("v|cpu>cpu@0|1|performance").is_err());
        assert!(LutKey::parse("v|cpu>gpu@750+250|1|performance").is_err());
        assert!(LutKey::parse("v|cpu>gpu|1|performance").is_err());
    }

    #[test]
    fn partitioned_lut_json_roundtrip_keeps_stages() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg)
            .with_runs(6, 1)
            .measure_with_partitions()
            .unwrap();
        let back = Lut::from_json(&lut.to_json()).unwrap();
        assert_eq!(back.len(), lut.len());
        for (k, e) in &lut.entries {
            let b = back.get(k).unwrap();
            assert_eq!(b.latency, e.latency);
            assert_eq!(b.stages.len(), e.stages.len());
            for (x, y) in b.stages.iter().zip(e.stages.iter()) {
                assert_eq!(x.engine, y.engine);
                assert_eq!(x.stage_ms, y.stage_ms);
                assert_eq!(x.xfer_ms, y.xfer_ms);
            }
        }
    }

    #[test]
    fn zero_noise_split_entry_is_the_pipelined_bottleneck() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let m = Measurer::new(&dev, &reg).with_runs(8, 2).with_noise_sigma(0.0);
        let plan = PartitionPlan {
            engines: vec![EngineKind::Gpu, EngineKind::Cpu],
            cuts_pm: vec![500],
        };
        let key = LutKey {
            variant: "deeplab_v3__int8__b1".into(),
            engine: EngineKind::Gpu,
            threads: perf::plan_threads(&dev, &plan.engines),
            governor: Governor::Performance,
            plan: ExecPlan::Split(plan),
        };
        let e = m.measure_plan(&key).unwrap();
        let bottleneck = perf::pipelined_latency_ms(&e.stages);
        assert!((e.latency.avg - bottleneck).abs() < 1e-9);
        // Pipelined latency is never below the slowest bare stage.
        for st in &e.stages {
            assert!(bottleneck >= st.stage_ms);
        }
    }

    #[test]
    fn scaled_engine_scales_split_stages_of_first_stage_engine() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg)
            .with_runs(6, 1)
            .measure_with_partitions()
            .unwrap();
        let scaled = lut.scaled_engine(EngineKind::Gpu, 1.5);
        for (k, e) in &lut.entries {
            let s = scaled.get(k).unwrap();
            if k.engine == EngineKind::Gpu {
                assert!((s.latency.avg - e.latency.avg * 1.5).abs() < 1e-9);
                for (x, y) in s.stages.iter().zip(e.stages.iter()) {
                    assert_eq!(x.stage_ms, y.stage_ms * 1.5);
                    assert_eq!(x.xfer_ms, y.xfer_ms * 1.5);
                }
            } else {
                assert_eq!(s.latency.avg, e.latency.avg);
            }
        }
    }
}
