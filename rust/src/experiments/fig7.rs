//! Fig 7: Runtime Manager behaviour under device load.
//!
//! MobileNetV2 1.4 on the Samsung A71, minimising p90 latency with ε = 0
//! (the FP32 reference model — which places the initial design on the GPU,
//! as in the paper).  External load on the active engine is ramped
//! exponentially (the paper's own load model) and the Runtime Manager is
//! expected to migrate engines to sustain latency; the figure compares the
//! adaptive run against the statically-selected initial design.

use anyhow::Result;

use crate::app::{AppConfig, Application};
use crate::device::EngineKind;
use crate::manager::Policy;
use crate::model::Registry;
use crate::optimizer::{Objective, SearchSpace};
use crate::perf;
use crate::util::stats::{geomean, Percentile};

/// Device the load-adaptation experiment runs on.
pub const DEVICE: &str = "samsung_a71";
/// Family the experiment serves (falls back on the synthetic registry).
pub const FAMILY: &str = "mobilenet_v2_140";

/// The paper's Fig 7 family when the real zoo is loaded; the synthetic
/// registry's MobileNet analogue in hermetic mode.
fn pick_family(registry: &Registry) -> &'static str {
    registry.family_or(FAMILY, "mobilenet_v2_100")
}

/// A point on the Fig 7 curve.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    /// Frame index of the sample.
    pub frame: u64,
    /// Injected GPU load at this frame.
    pub load_step: f64,
    /// Latency with the Runtime Manager adapting (ms).
    pub adaptive_ms: f64,
    /// Latency with the initial design pinned (ms).
    pub static_ms: f64,
    /// Engine the adaptive run used at this frame.
    pub engine: EngineKind,
}

/// The full Fig 7 trace: adaptive vs static under the load ramp.
#[derive(Debug, Clone)]
pub struct Fig7Result {
    /// Per-frame latency samples.
    pub points: Vec<LoadPoint>,
    /// (frame, from, to) engine migrations the manager issued.
    pub switches: Vec<(u64, EngineKind, EngineKind)>,
    /// Max and geo-mean latency reduction vs the static design after the
    /// first load step (paper: up to 2.7x, geo 1.55x).
    pub max_reduction: f64,
    /// Geo-mean latency reduction vs the static run.
    pub geo_reduction: f64,
    /// Engine of the initial optimised design.
    pub initial_engine: EngineKind,
}

/// Load ramp: 0.5 steps on the initially-chosen engine, then on the engine
/// the manager migrates to (generated adaptively below).
fn policy() -> Policy {
    Policy {
        check_interval_ms: 100.0,
        cooldown_ms: 400.0,
        ..Policy::default()
    }
}

/// Run the load-ramp experiment (adaptive vs static).
pub fn run(registry: &Registry, real_exec: bool) -> Result<Fig7Result> {
    let objective = Objective::MinLatency { stat: Percentile::P90, epsilon: 0.0 };
    let mut cfg = AppConfig::new(DEVICE, objective,
                                 SearchSpace::family(pick_family(registry)));
    cfg.real_exec = real_exec;
    cfg.lut_runs = 100;
    cfg.policy = policy();
    let mut app = Application::build(cfg, registry.clone())?;
    let initial = app.current_design().clone();
    let initial_engine = initial.hw.engine;

    // The static design's latency is computed analytically under the same
    // load trajectory (it never migrates).
    let static_variant = registry.get(&initial.variant).unwrap().clone();

    let mut points = Vec::new();
    let mut switches = Vec::new();
    let frames_per_step = 40u64;
    let ramp = [0.0, 0.5, 1.0, 1.5, 2.0, 2.0, 2.0];
    let mut load_on_initial;
    let mut second_engine: Option<EngineKind> = None;
    let mut load_on_second = 0.0;

    for (step, &load) in ramp.iter().enumerate() {
        // Apply this step's loads.
        load_on_initial = load;
        app.sim.set_load(initial_engine, load_on_initial);
        if step >= 5 {
            // Late phase: also load the engine the manager migrated to,
            // forcing the second switch (paper: GPU -> NNAPI -> CPU).
            if let Some(e2) = second_engine {
                load_on_second += 1.0;
                app.sim.set_load(e2, load_on_second);
            }
        }

        let recs = app.run(frames_per_step, &[])?;
        for r in &recs {
            if let Some(sw) = &r.switch {
                switches.push((r.seq, sw.from.hw.engine, sw.to.hw.engine));
                if sw.from.hw.engine == initial_engine && second_engine.is_none() {
                    second_engine = Some(sw.to.hw.engine);
                }
            }
            // Static design under the same conditions.
            let cond = perf::ExecConditions {
                governor: initial.hw.governor,
                threads: initial.hw.threads,
                load_factor: load_on_initial,
                thermal_freq_scale: 1.0,
            };
            let static_ms =
                perf::latency_ms(&app.profile, initial_engine, &static_variant, &cond)
                    .unwrap();
            points.push(LoadPoint {
                frame: r.seq,
                load_step: load_on_initial,
                adaptive_ms: r.latency_ms,
                static_ms,
                engine: r.engine,
            });
        }
    }

    let reductions: Vec<f64> = points
        .iter()
        .filter(|p| p.load_step > 0.0)
        .map(|p| p.static_ms / p.adaptive_ms)
        .collect();
    Ok(Fig7Result {
        max_reduction: reductions.iter().copied().fold(f64::MIN, f64::max),
        geo_reduction: geomean(&reductions),
        points,
        switches,
        initial_engine,
    })
}

/// Print the Fig 7 trace and summary.
pub fn print(registry: &Registry, real_exec: bool) -> Result<()> {
    let family = pick_family(registry);
    let r = run(registry, real_exec)?;
    println!("FIG 7 — Runtime Manager under device load ({family} on {DEVICE})");
    println!("initial engine: {}", r.initial_engine.name());
    // Down-sampled curve.
    println!("{:>6} {:>6} {:>12} {:>12} {:<6}",
             "frame", "load", "adaptive ms", "static ms", "engine");
    for p in r.points.iter().step_by(10) {
        println!("{:>6} {:>6.1} {:>12.4} {:>12.4} {:<6}",
                 p.frame, p.load_step, p.adaptive_ms, p.static_ms,
                 p.engine.name());
    }
    for (f, from, to) in &r.switches {
        println!("  switch at frame {f}: {} -> {}", from.name(), to.name());
    }
    println!(
        "latency reduction vs static design: up to {:.2}x ({:.2}x geo-mean)",
        r.max_reduction, r.geo_reduction
    );
    println!("(paper: up to 2.7x, 1.55x geo-mean; GPU -> NNAPI -> CPU migrations)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_fixtures::fake_registry;

    #[test]
    fn adaptation_beats_static_under_load() {
        // Uses the fake registry's mobilenet instead of the real one.
        let reg = fake_registry();
        // fake registry has no mobilenet_v2_140: run with 100.
        let r = run_with_family(&reg, "mobilenet_v2_100").unwrap();
        assert!(!r.switches.is_empty(), "no migrations under ramped load");
        assert!(r.max_reduction > 1.3, "max reduction {}", r.max_reduction);
        assert!(r.geo_reduction > 1.0, "geo {}", r.geo_reduction);
    }

    #[test]
    fn engines_migrate_in_sequence() {
        let reg = fake_registry();
        let r = run_with_family(&reg, "mobilenet_v2_100").unwrap();
        // Each switch leaves the currently-loaded engine.
        for (i, (_, from, to)) in r.switches.iter().enumerate() {
            assert_ne!(from, to);
            if i == 0 {
                assert_eq!(*from, r.initial_engine);
            }
        }
    }

    /// Test-only variant of `run` with a configurable family.
    fn run_with_family(reg: &Registry, family: &str) -> Result<Fig7Result> {
        let objective = Objective::MinLatency {
            stat: Percentile::P90,
            epsilon: 0.02,
        };
        let mut cfg = AppConfig::new(DEVICE, objective, SearchSpace::family(family));
        cfg.real_exec = false;
        cfg.lut_runs = 30;
        cfg.policy = policy();
        let mut app = Application::build(cfg, reg.clone())?;
        let initial = app.current_design().clone();
        let initial_engine = initial.hw.engine;
        let static_variant = reg.get(&initial.variant).unwrap().clone();
        let mut points = Vec::new();
        let mut switches = Vec::new();
        let mut second: Option<EngineKind> = None;
        let mut l2 = 0.0;
        for (step, &load) in [0.0, 1.0, 2.0, 2.5, 2.5].iter().enumerate() {
            app.sim.set_load(initial_engine, load);
            if step >= 4 {
                if let Some(e2) = second {
                    l2 += 1.5;
                    app.sim.set_load(e2, l2);
                }
            }
            let recs = app.run(40, &[])?;
            for r in &recs {
                if let Some(sw) = &r.switch {
                    switches.push((r.seq, sw.from.hw.engine, sw.to.hw.engine));
                    if sw.from.hw.engine == initial_engine && second.is_none() {
                        second = Some(sw.to.hw.engine);
                    }
                }
                let cond = perf::ExecConditions {
                    governor: initial.hw.governor,
                    threads: initial.hw.threads,
                    load_factor: load,
                    thermal_freq_scale: 1.0,
                };
                let static_ms = perf::latency_ms(
                    &app.profile, initial_engine, &static_variant, &cond).unwrap();
                points.push(LoadPoint {
                    frame: r.seq, load_step: load,
                    adaptive_ms: r.latency_ms, static_ms, engine: r.engine,
                });
            }
        }
        let reductions: Vec<f64> = points.iter().filter(|p| p.load_step > 0.0)
            .map(|p| p.static_ms / p.adaptive_ms).collect();
        Ok(Fig7Result {
            max_reduction: reductions.iter().copied().fold(f64::MIN, f64::max),
            geo_reduction: geomean(&reductions),
            points, switches, initial_engine,
        })
    }
}
