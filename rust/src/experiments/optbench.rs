//! Design-space / frontier adaptation benchmark (`oodin opt-bench`):
//! quantifies what the cached-Pareto-frontier refactor buys on every
//! adaptation path.
//!
//! For each device and each app of the canonical four-app mix, a fixed
//! sequence of condition events (load shifts, a thermal throttle, returns
//! to idle — the Fig 7/8 shapes) is replayed twice:
//!
//! * **full search** — enumerate + score the whole pre-filtered σ-space at
//!   the event's conditions bucket, exactly what every layer did before
//!   the refactor (O(space) per event);
//! * **frontier walk** — select from the bucket's cached Pareto frontier
//!   (built on first visit, reused on every repeat — O(frontier) per
//!   event).
//!
//! Both selections are asserted equal (the design-space layer's exactness
//! guarantee), and the driver reports enumerated-space size, frontier
//! size, per-event decision counts and simulated-µs adaptation cost
//! (decision counts × a nominal [`SIM_NS_PER_EVAL`] per scored candidate —
//! a deterministic stand-in for wall-clock so the smoke JSON is
//! byte-stable and golden-pinned, `tests/golden/optbench_smoke.json`).
//!
//! The smoke configuration measures its LUT with *zero* sampling noise so
//! the whole report is closed-form from the roofline model — the
//! independent Python oracle (`python/golden_optbench.py`) regenerates the
//! golden byte-for-byte without running this binary.

use anyhow::{ensure, Context, Result};

use std::sync::Arc;

use crate::designspace::{rank, ConditionsBucket, DesignSpace, FrontierCache,
                         LutDelta};
use crate::device::EngineKind;
use crate::manager::{design_id, Conditions};
use crate::mdcl;
use crate::measurements::{Lut, Measurer};
use crate::model::Registry;
use crate::optimizer::{Objective, SearchSpace};
use crate::telemetry::trace::FlightRecorder;
use crate::util::json::{self, Value};
use crate::util::stats::Percentile;

/// Nominal simulated cost of scoring one candidate (ns) — the unit behind
/// the report's deterministic µs figures.
pub const SIM_NS_PER_EVAL: u64 = 150;

/// Byte budget for one app's private frontier cache.  Generous — the five
/// smoke buckets sit far below it, so the golden pins zero evictions while
/// still exercising the resident-bytes accounting end to end.
pub const APP_CACHE_BUDGET_BYTES: u64 = 256 * 1024;

/// One condition event of the replayed adaptation sequence.
#[derive(Debug, Clone)]
pub struct BenchEvent {
    /// Event label in the report.
    pub name: &'static str,
    /// Conditions observed at this event.
    pub conds: Conditions,
}

/// Experiment dimensions and depth.
#[derive(Debug, Clone)]
pub struct OptBenchConfig {
    /// Device profiles to sweep.
    pub devices: Vec<String>,
    /// Measurement runs for the per-device LUT.
    pub lut_runs: usize,
    /// Log-normal sampling noise of the LUT measurement (0 = closed-form).
    pub noise_sigma: f64,
    /// Apps of the canonical mix to include (1..=4).
    pub n_apps: usize,
}

impl OptBenchConfig {
    /// The full sweep: all three Table I devices, paper-depth LUTs.
    pub fn full() -> Self {
        OptBenchConfig {
            devices: vec!["sony_c5".into(), "samsung_a71".into(),
                          "samsung_s20_fe".into()],
            lut_runs: 60,
            noise_sigma: 0.04,
            n_apps: 4,
        }
    }

    /// The CI-sized, golden-pinned configuration: one device, zero-noise
    /// LUT (latencies are exactly the roofline predictions).
    pub fn smoke() -> Self {
        OptBenchConfig {
            devices: vec!["samsung_a71".into()],
            lut_runs: 8,
            noise_sigma: 0.0,
            n_apps: 4,
        }
    }
}

/// The canonical four-app mix (same tuples as [`crate::app::multi_scenario`])
/// as (app_id, family, objective).
pub fn canonical_mix(n: usize) -> Vec<(&'static str, &'static str, Objective)> {
    let mix: [(&'static str, &'static str, Objective); 4] = [
        ("ai_camera", "mobilenet_v2_100",
         Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 }),
        ("video_conference", "efficientnet_lite4",
         Objective::MaxFps { epsilon: 0.05 }),
        ("gallery_tagger", "inception_v3",
         Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 }),
        ("scene_segmenter", "deeplab_v3",
         Objective::MinLatency { stat: Percentile::P90, epsilon: 0.05 }),
    ];
    mix.into_iter().take(n).collect()
}

/// The replayed condition sequence: load shifts, a repeat (the cache-hit
/// case), a thermal throttle, mixed pressure, and returns to idle.  Loads
/// are chosen on bucket centres (exact powers of two) so the smoke report
/// stays closed-form.
pub fn event_sequence() -> Vec<BenchEvent> {
    let mut events = Vec::new();
    let mut push = |name: &'static str,
                    loads: &[(EngineKind, f64)],
                    thermal: &[(EngineKind, f64)]| {
        let mut conds = Conditions::idle();
        for &(e, l) in loads {
            conds.loads.insert(e, l);
        }
        for &(e, t) in thermal {
            conds.thermal.insert(e, t);
        }
        events.push(BenchEvent { name, conds });
    };
    push("idle", &[], &[]);
    push("gpu_load", &[(EngineKind::Gpu, 1.0)], &[]);
    push("gpu_load_repeat", &[(EngineKind::Gpu, 1.0)], &[]);
    push("cpu_load", &[(EngineKind::Cpu, 2.0)], &[]);
    push("npu_throttle", &[], &[(EngineKind::Npu, 0.5)]);
    push("idle_return", &[], &[]);
    push("mixed", &[(EngineKind::Gpu, 1.0)], &[(EngineKind::Npu, 0.5)]);
    push("cpu_load_repeat", &[(EngineKind::Cpu, 2.0)], &[]);
    events
}

/// One adaptation event's decision record.
#[derive(Debug, Clone)]
pub struct EventRow {
    /// Event label.
    pub name: &'static str,
    /// Conditions-bucket id the event landed in.
    pub bucket: String,
    /// Candidates a full search scores at this event.
    pub full_evals: usize,
    /// Candidates the frontier walk scores at this event.
    pub frontier_evals: usize,
    /// True when this event built the bucket's frontier (first visit).
    pub built: bool,
    /// True when both selections agree (must always hold).
    pub selections_match: bool,
    /// The selected design, `variant|engine|threads|governor|r=..`.
    pub pick: String,
    /// Adjusted latency of the selection at the bucket's representative
    /// conditions (ms).
    pub latency_ms: f64,
}

/// One online LUT correction replayed through the incremental delta path
/// against the app's warm frontier cache.
#[derive(Debug, Clone)]
pub struct CorrectionRow {
    /// Correction label.
    pub name: &'static str,
    /// Cached frontiers carried across the transition in place.
    pub updated: u64,
    /// Frontier points / candidates the delta path touched.
    pub points_touched: u64,
    /// Candidates full rebuilds of the same frontiers would score — the
    /// cost the delta path must stay strictly under (the CI perf gate).
    pub rebuild_points: u64,
}

/// One (device, app) row of the report.
#[derive(Debug, Clone)]
pub struct AppRow {
    /// Device profile name.
    pub device: String,
    /// App id from the canonical mix.
    pub app: &'static str,
    /// Model family the app is built around.
    pub family: &'static str,
    /// Objective label.
    pub objective: String,
    /// Enumerated-space size after constraint pre-filtering.
    pub space_size: usize,
    /// Frontier size at the idle bucket.
    pub frontier_size_idle: usize,
    /// Per-event decision records.
    pub events: Vec<EventRow>,
    /// Σ full-search candidates over the events.
    pub full_evals_total: usize,
    /// Σ frontier-walk candidates over the events.
    pub frontier_evals_total: usize,
    /// Candidates enumerated by frontier builds (the amortised cost).
    pub frontier_build_evals: usize,
    /// Frontier builds (distinct buckets visited).
    pub builds: u64,
    /// Cache hits (events served without a build).
    pub hits: u64,
    /// Online LUT corrections replayed through the delta path after the
    /// adaptation sequence.
    pub corrections: Vec<CorrectionRow>,
    /// Frontier builds the post-correction verification replay caused
    /// (must be 0: corrections keep every bucket warm).
    pub post_correction_builds: u64,
    /// Accounted resident bytes of the frontier cache after the replay.
    pub resident_bytes: u64,
    /// Byte budget of the frontier cache.
    pub mem_budget: u64,
    /// LRU evictions (count-cap or byte-budget pressure).
    pub evictions: u64,
}

/// Human-readable objective tag for reports and cache keys.
pub fn objective_label(o: Objective) -> String {
    match o {
        Objective::MaxFps { epsilon } => format!("max_fps(eps={epsilon})"),
        Objective::TargetLatency { t_target_ms, stat } => {
            format!("target_latency({}ms,{})", t_target_ms, stat.name())
        }
        Objective::MaxAccMaxFps { w_fps } => {
            format!("max_acc_max_fps(w={w_fps})")
        }
        Objective::MinLatency { stat, epsilon } => {
            format!("min_latency({},eps={epsilon})", stat.name())
        }
    }
}

use super::r3;

/// Run one (device, app) adaptation replay.
fn run_app(device: &crate::device::DeviceProfile, registry: &Registry,
           lut: &crate::measurements::Lut, app: &'static str,
           family: &'static str, objective: Objective,
           recorder: Option<&Arc<FlightRecorder>>) -> Result<AppRow> {
    let space = DesignSpace::new(device, registry, lut);
    let sspace = SearchSpace::family(family);
    let mut cache = FrontierCache::new()
        .with_mem_budget(APP_CACHE_BUDGET_BYTES);
    if let Some(rec) = recorder {
        cache.set_recorder(Arc::clone(rec), app);
    }
    let mut events = Vec::new();
    let mut full_total = 0usize;
    let mut frontier_total = 0usize;
    let mut space_size = 0usize;
    let mut frontier_size_idle = 0usize;

    for (i, ev) in event_sequence().into_iter().enumerate() {
        // One virtual millisecond per adaptation event keeps the Chrome
        // trace timeline readable; opt-bench has no timeline of its own.
        if let Some(rec) = recorder {
            rec.set_now_us(i as u64 * 1_000);
        }
        let bucket = ConditionsBucket::of(&ev.conds);
        let rep = bucket.representative();

        // Full search: enumerate + score the whole space at this bucket —
        // the pre-refactor per-event cost.
        let cands = space.enumerate(objective, &sspace, &rep);
        let full_evals = cands.len();
        let full_ranked = rank(cands, objective);
        let full_pick = full_ranked
            .first()
            .with_context(|| format!("{app}: no feasible design at {}",
                                     bucket.id()))?;

        // Frontier walk: cached per bucket.
        let builds_before = cache.stats.builds;
        let frontier = cache.frontier(&space, objective, &sspace, &bucket);
        let built = cache.stats.builds > builds_before;
        let frontier_evals = frontier.len();
        let frontier_pick = frontier
            .best()
            .with_context(|| format!("{app}: empty frontier at {}",
                                     bucket.id()))?;

        // Strictly fewer whenever anything in the space is dominated; a
        // space that is already all-Pareto-optimal (tiny spaces on low-end
        // profiles) walks exactly its own size.  The smoke configuration
        // is strictly smaller on every event (asserted in tests and
        // pinned in the golden JSON).
        ensure!(
            frontier_evals <= full_evals,
            "{app}@{}: frontier walk ({frontier_evals}) must never evaluate \
             more candidates than full search ({full_evals})",
            ev.name
        );
        let selections_match = frontier_pick.design == full_pick.design;
        ensure!(selections_match,
                "{app}@{}: frontier pick {} != full-search pick {}",
                ev.name, design_id(&frontier_pick.design),
                design_id(&full_pick.design));

        space_size = full_evals;
        if bucket.is_idle() {
            frontier_size_idle = frontier_evals;
        }
        full_total += full_evals;
        frontier_total += frontier_evals;
        events.push(EventRow {
            name: ev.name,
            bucket: bucket.id(),
            full_evals,
            frontier_evals,
            built,
            selections_match,
            pick: design_id(&frontier_pick.design),
            latency_ms: r3(frontier_pick.latency_ms),
        });
    }

    // Snapshot replay-phase counters: the correction + verification
    // phases below serve every event as a cache hit and would otherwise
    // skew the adaptation-phase figures the table reports.
    let builds = cache.stats.builds;
    let hits = cache.stats.hits;
    let frontier_build_evals = cache.stats.candidates_enumerated as usize;

    // -- online LUT corrections through the incremental delta path --------
    // Three correction shapes, replayed sequentially against the warm
    // cache: a per-engine scale (the fleet probe fallback's shape), a
    // re-measurement of individual entries, and an entry retirement.
    // Each must keep every cached frontier warm and touch strictly fewer
    // points than the full rebuilds it replaces — the CI perf gate,
    // golden-pinned in smoke mode.
    if let Some(rec) = recorder {
        rec.set_now_us(event_sequence().len() as u64 * 1_000);
    }
    let mut corrections = Vec::new();
    let mut apply = |cur: &Lut, next: &Lut, delta: &LutDelta,
                     name: &'static str| -> Result<CorrectionRow> {
        let old_ds = DesignSpace::new(device, registry, cur);
        let new_ds = DesignSpace::new(device, registry, next);
        let out = cache.apply_delta(&old_ds, &new_ds, delta);
        ensure!(out.dropped == 0,
                "{app}/{name}: correction dropped {} warm frontiers",
                out.dropped);
        ensure!(out.updated == 0 || out.points_touched < out.rebuild_points,
                "{app}/{name}: delta path touched {} points but full \
                 rebuilds would score only {}",
                out.points_touched, out.rebuild_points);
        Ok(CorrectionRow {
            name,
            updated: out.updated,
            points_touched: out.points_touched,
            rebuild_points: out.rebuild_points,
        })
    };

    // 1. The probe-fallback shape: every GPU row 25% slower.
    let next = lut.scaled_engine(EngineKind::Gpu, 1.25);
    corrections.push(apply(lut, &next,
                           &LutDelta::engine_scale(EngineKind::Gpu, 1.25),
                           "gpu_scale_1.25")?);
    let cur = next;

    // 2. Re-measurement: the family's FP32 CPU rows come back 5% slower.
    let mut next = cur.clone();
    let fp32 = format!("{family}__fp32__b1");
    for (k, e) in next.entries.iter_mut() {
        if k.variant == fp32 && k.engine == EngineKind::Cpu {
            e.latency = e.latency.scaled(1.05);
        }
    }
    corrections.push(apply(&cur, &next, &LutDelta::between(&cur, &next),
                           "remeasure_fp32_cpu")?);
    let cur = next;

    // 3. Retirement: the family's INT8 GPU rows are withdrawn.
    let int8 = format!("{family}__int8__b1");
    let mut next = cur.clone();
    next.entries
        .retain(|k, _| !(k.variant == int8 && k.engine == EngineKind::Gpu));
    corrections.push(apply(&cur, &next, &LutDelta::between(&cur, &next),
                           "retire_int8_gpu")?);
    let cur = next;

    // Post-correction differential check: every bucket must still be warm
    // (zero rebuilds) and frontier-walk selection must agree with a full
    // search over the corrected LUT on every event.
    let builds_before_verify = cache.stats.builds;
    let corrected = DesignSpace::new(device, registry, &cur);
    for ev in event_sequence() {
        let bucket = ConditionsBucket::of(&ev.conds);
        let rep = bucket.representative();
        let full = rank(corrected.enumerate(objective, &sspace, &rep),
                        objective);
        let frontier = cache.frontier(&corrected, objective, &sspace,
                                      &bucket);
        let walk_pick = frontier.best().map(|c| design_id(&c.design));
        let full_pick = full.first().map(|c| design_id(&c.design));
        ensure!(walk_pick == full_pick,
                "{app}@{} post-correction: frontier pick {walk_pick:?} != \
                 full-search pick {full_pick:?}",
                ev.name);
    }
    let post_correction_builds = cache.stats.builds - builds_before_verify;
    ensure!(post_correction_builds == 0,
            "{app}: corrections left {post_correction_builds} buckets cold");

    Ok(AppRow {
        device: device.name.to_string(),
        app,
        family,
        objective: objective_label(objective),
        space_size,
        frontier_size_idle,
        events,
        full_evals_total: full_total,
        frontier_evals_total: frontier_total,
        frontier_build_evals,
        builds,
        hits,
        corrections,
        post_correction_builds,
        resident_bytes: cache.resident_bytes(),
        mem_budget: cache.mem_budget(),
        evictions: cache.stats.evictions,
    })
}

/// Run the full (device × app) sweep.
pub fn run(registry: &Registry, cfg: &OptBenchConfig) -> Result<Vec<AppRow>> {
    run_traced(registry, cfg, None)
}

/// [`run`] with an optional flight recorder: every per-app frontier-cache
/// transition (build, hit, delta application) is recorded, scoped by app
/// id, stamped one virtual millisecond per adaptation event.
pub fn run_traced(registry: &Registry, cfg: &OptBenchConfig,
                  recorder: Option<&Arc<FlightRecorder>>)
                  -> Result<Vec<AppRow>> {
    let mut rows = Vec::new();
    for device_name in &cfg.devices {
        let device = mdcl::detect(device_name)?;
        let lut = Measurer::new(&device, registry)
            .with_runs(cfg.lut_runs, (cfg.lut_runs / 10).max(1))
            .with_noise_sigma(cfg.noise_sigma)
            .measure_all()?;
        for (app, family, objective) in canonical_mix(cfg.n_apps) {
            match run_app(&device, registry, &lut, app, family, objective,
                          recorder) {
                Ok(row) => rows.push(row),
                // A family can be undeployable on a low-end profile (the
                // Fig 4 filter); the mix degrades gracefully, like the
                // multi-app scenario does.
                Err(e) if format!("{e:#}").contains("no feasible design") => {
                    eprintln!("note: {device_name}/{app}: {e:#}");
                }
                Err(e) => return Err(e),
            }
        }
    }
    Ok(rows)
}

fn cost_us(evals: usize) -> f64 {
    r3(evals as f64 * SIM_NS_PER_EVAL as f64 / 1000.0)
}

fn rows_to_json(rows: &[AppRow]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                let events = r
                    .events
                    .iter()
                    .map(|e| {
                        json::obj(vec![
                            ("name", json::s(e.name)),
                            ("bucket", json::s(&e.bucket)),
                            ("full_evals", json::num(e.full_evals as f64)),
                            ("frontier_evals",
                             json::num(e.frontier_evals as f64)),
                            ("built", Value::Bool(e.built)),
                            ("match", Value::Bool(e.selections_match)),
                            ("pick", json::s(&e.pick)),
                            ("latency_ms", json::num(e.latency_ms)),
                        ])
                    })
                    .collect();
                let amortised = r.frontier_evals_total + r.frontier_build_evals;
                let corrections: Vec<Value> = r
                    .corrections
                    .iter()
                    .map(|c| {
                        json::obj(vec![
                            ("name", json::s(c.name)),
                            ("updated", json::num(c.updated as f64)),
                            ("points_touched",
                             json::num(c.points_touched as f64)),
                            ("rebuild_points",
                             json::num(c.rebuild_points as f64)),
                        ])
                    })
                    .collect();
                let touched_total: u64 =
                    r.corrections.iter().map(|c| c.points_touched).sum();
                let rebuild_total: u64 =
                    r.corrections.iter().map(|c| c.rebuild_points).sum();
                let n_events = r.events.len() as f64;
                let dps = |evals: usize| {
                    r3(n_events * 1e9
                       / (SIM_NS_PER_EVAL as f64 * evals as f64))
                };
                json::obj(vec![
                    ("device", json::s(&r.device)),
                    ("app", json::s(r.app)),
                    ("family", json::s(r.family)),
                    ("objective", json::s(&r.objective)),
                    ("space_size", json::num(r.space_size as f64)),
                    ("frontier_size_idle",
                     json::num(r.frontier_size_idle as f64)),
                    ("events", Value::Arr(events)),
                    ("full_evals_total", json::num(r.full_evals_total as f64)),
                    ("frontier_evals_total",
                     json::num(r.frontier_evals_total as f64)),
                    ("frontier_build_evals",
                     json::num(r.frontier_build_evals as f64)),
                    ("builds", json::num(r.builds as f64)),
                    ("hits", json::num(r.hits as f64)),
                    ("full_cost_us", json::num(cost_us(r.full_evals_total))),
                    ("frontier_walk_cost_us",
                     json::num(cost_us(r.frontier_evals_total))),
                    ("frontier_cost_us_amortized",
                     json::num(cost_us(amortised))),
                    ("walk_speedup",
                     json::num(r3(r.full_evals_total as f64
                                  / r.frontier_evals_total as f64))),
                    ("corrections", Value::Arr(corrections)),
                    ("delta_points_touched",
                     json::num(touched_total as f64)),
                    ("delta_rebuild_points",
                     json::num(rebuild_total as f64)),
                    ("delta_lt_rebuild",
                     Value::Bool(touched_total < rebuild_total)),
                    ("post_correction_builds",
                     json::num(r.post_correction_builds as f64)),
                    ("cache_resident_bytes",
                     json::num(r.resident_bytes as f64)),
                    ("cache_mem_budget", json::num(r.mem_budget as f64)),
                    ("cache_evictions", json::num(r.evictions as f64)),
                    ("cache_under_budget",
                     Value::Bool(r.resident_bytes <= r.mem_budget)),
                    ("decisions_per_sec_full",
                     json::num(dps(r.full_evals_total))),
                    ("decisions_per_sec_frontier",
                     json::num(dps(r.frontier_evals_total))),
                ])
            })
            .collect(),
    )
}

/// The complete report as one JSON value (the golden-pinned payload).
pub fn report_json(rows: &[AppRow], cfg: &OptBenchConfig) -> Value {
    json::obj(vec![(
        "opt_bench",
        json::obj(vec![
            ("lut_runs", json::num(cfg.lut_runs as f64)),
            ("noise_sigma", json::num(cfg.noise_sigma)),
            ("sim_ns_per_eval", json::num(SIM_NS_PER_EVAL as f64)),
            ("rows", rows_to_json(rows)),
        ]),
    )])
}

/// Print the adaptation-cost table; also emit the rows as a JSON line and,
/// when `json_out` is given, write them to that file.  With `trace_out`,
/// the run is flight-recorded and exported as JSON-lines at that path
/// plus Chrome trace-event JSON at `<trace_out>.chrome.json`.
pub fn print(registry: &Registry, cfg: &OptBenchConfig,
             json_out: Option<&str>, trace_out: Option<&str>) -> Result<()> {
    let recorder = trace_out.map(|_| Arc::new(FlightRecorder::new()));
    let rows = run_traced(registry, cfg, recorder.as_ref())?;
    println!("OPT-BENCH — full σ-space search vs cached Pareto-frontier \
              walk per adaptation event");
    println!("{:<15} {:<16} {:>5} {:>5} | {:>7} {:>7} {:>5} {:>4} | {:>9} \
              {:>9} {:>7}",
             "device", "app", "space", "front", "full#", "walk#", "build",
             "hit", "full µs", "walk µs", "speedup");
    println!("{}", super::rule(100));
    for r in &rows {
        println!("{:<15} {:<16} {:>5} {:>5} | {:>7} {:>7} {:>5} {:>4} | \
                  {:>9.1} {:>9.1} {:>6.1}x",
                 r.device, r.app, r.space_size, r.frontier_size_idle,
                 r.full_evals_total, r.frontier_evals_total, r.builds,
                 r.hits, cost_us(r.full_evals_total),
                 cost_us(r.frontier_evals_total),
                 r.full_evals_total as f64 / r.frontier_evals_total as f64);
    }
    println!("(space = enumerated candidates after pre-filtering; front = \
              idle-bucket frontier; full#/walk# = candidates scored over \
              {} adaptation events; µs simulated at {} ns/candidate; \
              selections verified equal on every event)",
             event_sequence().len(), SIM_NS_PER_EVAL);
    println!("incremental corrections (delta path vs full rebuild, points \
              touched):");
    for r in &rows {
        let touched: u64 =
            r.corrections.iter().map(|c| c.points_touched).sum();
        let rebuild: u64 =
            r.corrections.iter().map(|c| c.rebuild_points).sum();
        println!("  {:<16} {} corrections: {} pts touched vs {} rebuild \
                  ({} frontiers kept warm, {} B resident / {} B budget)",
                 r.app, r.corrections.len(), touched, rebuild,
                 r.corrections.iter().map(|c| c.updated).max().unwrap_or(0),
                 r.resident_bytes, r.mem_budget);
    }
    if let (Some(path), Some(rec)) = (trace_out, &recorder) {
        std::fs::write(path, rec.to_jsonl())
            .with_context(|| format!("writing {path}"))?;
        let chrome = format!("{path}.chrome.json");
        std::fs::write(&chrome, rec.to_chrome_trace())
            .with_context(|| format!("writing {chrome}"))?;
        println!("trace: {} events ({} dropped) to {path}; Chrome trace \
                  to {chrome}",
                 rec.len(), rec.dropped());
    }
    let payload = report_json(&rows, cfg);
    let line = json::to_string(&payload);
    println!("OPTBENCH_JSON {line}");
    if let Some(path) = json_out {
        std::fs::write(path, &line)
            .with_context(|| format!("writing {path}"))?;
        println!("JSON written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_fixtures::fake_registry;

    #[test]
    fn smoke_rows_cover_mix_and_beat_full_search() {
        let reg = fake_registry();
        let rows = run(&reg, &OptBenchConfig::smoke()).unwrap();
        assert_eq!(rows.len(), 4, "all four apps deployable on the A71");
        for r in &rows {
            assert!(r.frontier_evals_total < r.full_evals_total, "{r:?}");
            assert!(r.builds >= 1 && r.hits >= 1, "{r:?}");
            for e in &r.events {
                assert!(e.selections_match);
                assert!(e.frontier_evals < e.full_evals);
            }
            // Repeated buckets never rebuild.
            let repeat = r.events.iter().find(|e| e.name == "gpu_load_repeat");
            assert!(!repeat.unwrap().built);
            // The incremental-correction gate: every correction keeps all
            // frontiers warm and beats the rebuilds it replaces.
            assert_eq!(r.corrections.len(), 3, "{r:?}");
            for c in &r.corrections {
                assert_eq!(c.updated, r.builds, "{c:?}");
                assert!(c.points_touched < c.rebuild_points, "{c:?}");
            }
            assert_eq!(r.post_correction_builds, 0, "{r:?}");
            assert_eq!(r.evictions, 0, "{r:?}");
            assert!(r.resident_bytes > 0 && r.resident_bytes <= r.mem_budget,
                    "{r:?}");
        }
    }

    #[test]
    fn event_sequence_revisits_buckets() {
        let evs = event_sequence();
        let b = |n: &str| {
            ConditionsBucket::of(
                &evs.iter().find(|e| e.name == n).unwrap().conds)
        };
        assert_eq!(b("gpu_load"), b("gpu_load_repeat"));
        assert_eq!(b("idle"), b("idle_return"));
        assert!(b("idle").is_idle());
        assert_ne!(b("npu_throttle"), b("mixed"));
    }
}
