//! Experiment drivers: one per table/figure of the paper's evaluation
//! (§IV).  Each driver returns structured results and offers a printer that
//! emits rows comparable with the paper's — the bench targets and the
//! `oodin exp <id>` CLI both call these.

pub mod coexec;
pub mod fig3;
pub mod fig456;
pub mod fig7;
pub mod fig8;
pub mod fleetbench;
pub mod loadgen;
pub mod multiapp;
pub mod optbench;
pub mod tables;

use std::sync::Arc;

use anyhow::Result;

use crate::device::DeviceProfile;
use crate::measurements::{Lut, Measurer};
use crate::model::Registry;

/// The accuracy-drop tolerance used across the evaluation: the paper states
/// "no accuracy drop allowed" while its baselines run INT8 variants whose
/// Table II drops are 0.5-1.3%; we read this as "no *catastrophic* drop"
/// and use a 1.5% ε uniformly (see EXPERIMENTS.md).
pub const EVAL_EPSILON: f64 = 0.015;

/// Measurement depth for experiment LUTs (paper protocol: 200 runs).
pub const EVAL_RUNS: usize = 200;
/// Warm-up runs discarded before the measured runs.
pub const EVAL_WARMUP: usize = 15;

/// Build the device LUT used by an experiment.
pub fn build_lut(device: &DeviceProfile, registry: &Registry) -> Result<Arc<Lut>> {
    Ok(Arc::new(
        Measurer::new(device, registry)
            .with_runs(EVAL_RUNS, EVAL_WARMUP)
            .measure_all()?,
    ))
}

/// Pretty horizontal rule for report printers.
pub fn rule(width: usize) -> String {
    "-".repeat(width)
}

/// Round to 3 decimals — the numeric resolution of every golden-pinned
/// report JSON (serve-bench, opt-bench, fleet-bench share one rounding
/// convention, mirrored by the Python oracles).
pub(crate) fn r3(x: f64) -> f64 {
    (x * 1000.0).round() / 1000.0
}
