//! Fig 3: OODIn vs optimised status-quo (oSQ-CPU / -GPU / -NNAPI) across
//! devices and models.
//!
//! Objective (paper §IV-B): minimise *average* latency with no accuracy
//! drop allowed (ε per `EVAL_EPSILON`).  Baseline spaces:
//!
//! * oSQ-CPU — CPU only, XNNPACK-style INT8 allowed, threads tuned
//!   (equivalent to the SOTA CPU design of [9], which is quantised).
//! * oSQ-GPU — GPU only, fastest of FP16/INT8 (paper's definition).
//! * oSQ-NNAPI — the vendor NPU, any precision.
//!
//! Reported: per-(device, model) speedup of OODIn over each baseline, plus
//! per-device geometric means and maxima — the numbers the paper summarises
//! as up to 4.14x / 4.29x / 93.46x (geo 1.73 / 1.74 / 5.9).

use anyhow::Result;

use crate::device::{profiles::profiles, EngineKind};
use crate::experiments::{build_lut, EVAL_EPSILON};
use crate::model::{Precision, Registry};
use crate::optimizer::{Objective, Optimizer, SearchSpace};
use crate::util::stats::{geomean, Percentile};

/// One (device, family) comparison row.
#[derive(Debug, Clone)]
pub struct Fig3Row {
    /// Device profile name.
    pub device: String,
    /// Model family compared.
    pub family: String,
    /// OODIn's optimised latency (ms).
    pub oodin_ms: f64,
    /// Engine OODIn selected.
    pub oodin_engine: EngineKind,
    /// Baseline latency per engine; None = not deployable on that engine.
    pub osq_cpu_ms: Option<f64>,
    /// oSQ-GPU baseline latency (ms).
    pub osq_gpu_ms: Option<f64>,
    /// oSQ-NNAPI baseline latency (ms).
    pub osq_nnapi_ms: Option<f64>,
}

impl Fig3Row {
    /// OODIn's speedup over one baseline latency.
    pub fn speedup(&self, baseline: Option<f64>) -> Option<f64> {
        baseline.map(|b| b / self.oodin_ms)
    }
}

/// Aggregates per device.
#[derive(Debug, Clone)]
pub struct Fig3Summary {
    /// Device profile name.
    pub device: String,
    /// (geo-mean, max) speedup over the oSQ-CPU baseline.
    pub vs_cpu: (f64, f64),
    /// (geo-mean, max) speedup over the oSQ-GPU baseline.
    pub vs_gpu: (f64, f64),
    /// (geo-mean, max) speedup over oSQ-NNAPI (None without an NPU).
    pub vs_nnapi: Option<(f64, f64)>,
}

/// Compute every (device, family) row and the per-device summaries.
pub fn run(registry: &Registry) -> Result<(Vec<Fig3Row>, Vec<Fig3Summary>)> {
    let objective = Objective::MinLatency {
        stat: Percentile::Avg,
        epsilon: EVAL_EPSILON,
    };
    let mut rows = Vec::new();
    let mut summaries = Vec::new();

    for device in profiles() {
        let lut = build_lut(&device, registry)?;
        let opt = Optimizer::new(&device, registry, &lut);

        let mut dev_rows = Vec::new();
        for family in registry.families() {
            let free = SearchSpace::family(family);
            let Ok(oodin) = opt.optimize(objective, &free) else {
                continue; // family not deployable on this device at all
            };

            let base = |engines: &[EngineKind], precs: Option<&[Precision]>| {
                let mut space = SearchSpace::family(family).with_engines(engines);
                if let Some(p) = precs {
                    space = space.with_precisions(p);
                }
                opt.optimize(objective, &space).ok().map(|e| e.latency_ms)
            };

            dev_rows.push(Fig3Row {
                device: device.name.to_string(),
                family: family.to_string(),
                oodin_ms: oodin.latency_ms,
                oodin_engine: oodin.design.hw.engine,
                osq_cpu_ms: base(&[EngineKind::Cpu], None),
                osq_gpu_ms: base(&[EngineKind::Gpu],
                                 Some(&[Precision::Fp16, Precision::Int8])),
                osq_nnapi_ms: base(&[EngineKind::Npu], None),
            });
        }

        let agg = |pick: fn(&Fig3Row) -> Option<f64>| -> Option<(f64, f64)> {
            let sp: Vec<f64> = dev_rows
                .iter()
                .filter_map(|r| r.speedup(pick(r)))
                .collect();
            if sp.is_empty() {
                None
            } else {
                Some((geomean(&sp), sp.iter().copied().fold(f64::MIN, f64::max)))
            }
        };
        summaries.push(Fig3Summary {
            device: device.name.to_string(),
            vs_cpu: agg(|r| r.osq_cpu_ms).unwrap_or((1.0, 1.0)),
            vs_gpu: agg(|r| r.osq_gpu_ms).unwrap_or((1.0, 1.0)),
            vs_nnapi: agg(|r| r.osq_nnapi_ms),
        });
        rows.extend(dev_rows);
    }
    Ok((rows, summaries))
}

/// Print the Fig 3 comparison table.
pub fn print(registry: &Registry) -> Result<()> {
    let (rows, summaries) = run(registry)?;
    println!("FIG 3 — OODIn vs optimised status-quo designs");
    println!("{:<14} {:<20} {:>9} {:<6} {:>9} {:>9} {:>9}",
             "device", "model", "OODIn ms", "eng", "xCPU", "xGPU", "xNNAPI");
    let fmt = |s: Option<f64>| s.map_or("   n/a".to_string(), |x| format!("{x:8.2}x"));
    for r in &rows {
        println!(
            "{:<14} {:<20} {:>9.4} {:<6} {} {} {}",
            r.device,
            r.family,
            r.oodin_ms,
            r.oodin_engine.name(),
            fmt(r.speedup(r.osq_cpu_ms)),
            fmt(r.speedup(r.osq_gpu_ms)),
            fmt(r.speedup(r.osq_nnapi_ms)),
        );
    }
    println!("{}", crate::experiments::rule(80));
    for s in &summaries {
        println!(
            "{:<14} geo/max over oSQ-CPU {:.2}x/{:.2}x  oSQ-GPU {:.2}x/{:.2}x  oSQ-NNAPI {}",
            s.device,
            s.vs_cpu.0, s.vs_cpu.1,
            s.vs_gpu.0, s.vs_gpu.1,
            s.vs_nnapi.map_or("n/a".into(),
                              |(g, m)| format!("{g:.2}x/{m:.2}x")),
        );
    }
    println!("(paper: up to 4.14x / 4.29x / 93.46x; geo 1.73 / 1.74 / 5.9)");
    Ok(())
}

/// The "best engine varies per (model, device)" matrix (§IV-B).
pub fn engine_matrix(registry: &Registry) -> Result<Vec<(String, String, EngineKind)>> {
    let (rows, _) = run(registry)?;
    Ok(rows
        .into_iter()
        .map(|r| (r.device, r.family, r.oodin_engine))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_fixtures::fake_registry;

    #[test]
    fn oodin_never_loses_to_a_baseline() {
        let reg = fake_registry();
        let (rows, _) = run(&reg).unwrap();
        assert!(!rows.is_empty());
        for r in &rows {
            for b in [r.osq_cpu_ms, r.osq_gpu_ms, r.osq_nnapi_ms].into_iter().flatten() {
                assert!(r.oodin_ms <= b + 1e-9,
                        "{}/{}: oodin {} > baseline {}", r.device, r.family,
                        r.oodin_ms, b);
            }
        }
    }

    #[test]
    fn sony_has_no_nnapi_baseline() {
        let reg = fake_registry();
        let (rows, summaries) = run(&reg).unwrap();
        assert!(rows.iter().filter(|r| r.device == "sony_c5")
                .all(|r| r.osq_nnapi_ms.is_none()));
        let sony = summaries.iter().find(|s| s.device == "sony_c5").unwrap();
        assert!(sony.vs_nnapi.is_none());
    }

    #[test]
    fn best_engine_varies_across_pairs() {
        // §IV-B's core observation: no single engine wins everywhere.
        let reg = fake_registry();
        let m = engine_matrix(&reg).unwrap();
        let engines: std::collections::BTreeSet<_> =
            m.iter().map(|(_, _, e)| *e).collect();
        assert!(engines.len() >= 2, "engine choice should vary: {m:?}");
    }

    #[test]
    fn geomeans_at_least_one() {
        let reg = fake_registry();
        let (_, summaries) = run(&reg).unwrap();
        for s in summaries {
            assert!(s.vs_cpu.0 >= 1.0 - 1e-9);
            assert!(s.vs_gpu.0 >= 1.0 - 1e-9);
        }
    }
}
