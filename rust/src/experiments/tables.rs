//! Table I (target platforms) and Table II (evaluated DNNs) reproductions.
//!
//! Table I is rendered from the device profiles (resource side is the
//! paper's data verbatim).  Table II is *regenerated from measurements*:
//! accuracy comes from the held-out evaluation the compile path ran, and
//! params / size / FLOPs from the cost model — nothing is copied from the
//! paper.

use crate::device::profiles::profiles;
use crate::mdcl;
use crate::model::{Precision, Registry};

/// One Table II row.
#[derive(Debug, Clone)]
pub struct Table2Row {
    /// Table II model name.
    pub paper_name: String,
    /// In-repo family standing in for it.
    pub family: String,
    /// Transformation of this row.
    pub precision: Precision,
    /// Input resolution.
    pub resolution: usize,
    /// Measured accuracy.
    pub accuracy: f64,
    /// Metric `accuracy` reports.
    pub accuracy_metric: String,
    /// Trained parameters.
    pub params: u64,
    /// Serialized weight bytes.
    pub size_bytes: u64,
    /// FLOPs per inference.
    pub flops: u64,
}

/// Regenerate Table II (FP32 + INT8 rows, like the paper; FP16 accuracy is
/// within noise of FP32's and is omitted from the table, as the paper does).
/// Build the Table II rows from the loaded registry.
pub fn table2(registry: &Registry) -> Vec<Table2Row> {
    let mut rows: Vec<Table2Row> = registry
        .variants()
        .iter()
        .filter(|v| v.batch == 1 && v.precision != Precision::Fp16)
        .map(|v| Table2Row {
            paper_name: v.paper_name.clone(),
            family: v.family.clone(),
            precision: v.precision,
            resolution: v.resolution,
            accuracy: v.accuracy,
            accuracy_metric: v.accuracy_metric.clone(),
            params: v.params,
            size_bytes: v.size_bytes,
            flops: v.flops,
        })
        .collect();
    // Paper orders Table II by ascending accuracy.
    rows.sort_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap());
    rows
}

/// Print Table II (the model zoo under each transformation).
pub fn print_table2(registry: &Registry) {
    println!("TABLE II — EVALUATED DEEP NEURAL NETWORKS (regenerated)");
    println!("{:<20} {:<5} {:>5} {:>12} {:>9} {:>9} {:>8}",
             "DNN", "Prec", "Res", "Top-1/mIoU", "Params", "Size", "FLOPs");
    for r in table2(registry) {
        println!(
            "{:<20} {:<5} {:>5} {:>11.1}% {:>8.2}K {:>7.2}KB {:>7.1}M",
            r.paper_name,
            r.precision.name(),
            format!("{0}x{0}", r.resolution),
            r.accuracy * 100.0,
            r.params as f64 / 1e3,
            r.size_bytes as f64 / 1e3,
            r.flops as f64 / 1e6,
        );
    }
    println!("(scaled-down zoo: see DESIGN.md §Substitutions; orderings mirror the paper)");
}

/// Render Table I from the device profiles.
/// Print Table I (the three device profiles).
pub fn print_table1() {
    println!("TABLE I — TARGET PLATFORMS");
    let devs = profiles();
    println!("{:<12} {:<18} {:>5} {:>6} {:>4} {:>8} {:>9} {:>8}",
             "Device", "Chipset", "Year", "Cores", "NPU", "RAM", "Android", "Battery");
    for d in &devs {
        println!(
            "{:<12} {:<18} {:>5} {:>6} {:>4} {:>6}GB {:>4} (API{:>2}) {:>5}mAh",
            d.name,
            d.chipset,
            d.year,
            d.n_cores,
            if d.has_engine(crate::device::EngineKind::Npu) { "yes" } else { "no" },
            d.ram_gb,
            d.os_version,
            d.api_level,
            d.battery_mah,
        );
    }
    for d in &devs {
        println!("  R({}) = {}", d.name, mdcl::format_resource_model(d));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_fixtures::fake_registry;

    #[test]
    fn table2_has_fp32_and_int8_rows_only() {
        let reg = fake_registry();
        let rows = table2(&reg);
        assert_eq!(rows.len(), 8); // 4 families x 2 precisions
        assert!(rows.iter().all(|r| r.precision != Precision::Fp16));
    }

    #[test]
    fn table2_sorted_by_accuracy() {
        let reg = fake_registry();
        let rows = table2(&reg);
        for w in rows.windows(2) {
            assert!(w[0].accuracy <= w[1].accuracy);
        }
    }
}
