//! Fig 8: Runtime Manager behaviour under thermal throttling.
//!
//! InceptionV3 on the Samsung A71 processing a *continuous* camera stream
//! (throughput-driven: no idle gaps, so the active engine overheats and the
//! DVFS governor cuts its clock).  The paper observes: initial NNAPI design;
//! performance collapses after ~85 processed images; the manager detects it
//! within ~800 ms and migrates (NNAPI -> GPU), the GPU later throttles too
//! (detected ~1150 ms) and execution lands on the CPU.
//!
//! Timescale note: our scaled workloads run ~1000x faster than the physical
//! phones', so the manager's check interval is scaled accordingly and the
//! detection delay is reported both in scaled ms and in *processed frames*
//! (the paper's x-axis).

use anyhow::Result;

use crate::device::EngineKind;
use crate::devicesim::DeviceSim;
use crate::manager::{Conditions, Policy, RuntimeManager, Switch};
use crate::measurements::Measurer;
use crate::model::Registry;
use crate::optimizer::{Objective, Optimizer, SearchSpace};
use crate::util::clock::Clock;
use crate::util::stats::Percentile;

/// Device the thermal experiment runs on.
pub const DEVICE: &str = "samsung_a71";
/// Family heavy enough to reach throttling (Fig 8's ~85 images).
pub const FAMILY: &str = "inception_v3";

/// One sample of the sustained-inference thermal trace.
#[derive(Debug, Clone)]
pub struct ThermalPoint {
    /// Inference index of the sample.
    pub inference: u64,
    /// Simulated latency (ms).
    pub latency_ms: f64,
    /// Engine in use.
    pub engine: EngineKind,
    /// Active-engine temperature (deg C).
    pub temp_c: f64,
    /// Thermal frequency scale in effect.
    pub thermal_scale: f64,
}

/// The full Fig 8 trace with the manager's thermal migrations.
#[derive(Debug, Clone)]
pub struct Fig8Result {
    /// Per-inference samples.
    pub points: Vec<ThermalPoint>,
    /// (inference, switch) reconfigurations the manager issued.
    pub switches: Vec<(u64, Switch)>,
    /// Engine of the initial optimised design.
    pub initial_engine: EngineKind,
    /// Inference index at which the first engine started throttling.
    pub first_throttle_at: Option<u64>,
}

/// Run the sustained-inference thermal experiment.
pub fn run(registry: &Registry, n_inferences: u64) -> Result<Fig8Result> {
    let device = crate::mdcl::detect(DEVICE)?;
    let lut = std::sync::Arc::new(
        Measurer::new(&device, registry).with_runs(100, 10).measure_all()?,
    );
    let objective = Objective::MinLatency {
        stat: Percentile::Avg,
        epsilon: crate::experiments::EVAL_EPSILON,
    };
    let space = SearchSpace::family(FAMILY);
    let opt = Optimizer::new(&device, registry, &lut);
    let initial = opt.optimize(objective, &space)?.design;
    let initial_engine = initial.hw.engine;

    let registry_arc = std::sync::Arc::new(registry.clone());
    let device_arc = std::sync::Arc::new(device.clone());
    // Expected per-inference latency sets the adaptation timescale (see
    // module docs): check every ~3 inferences, confirm over 3 checks.
    let expected = lut.get(&initial.lut_key()).unwrap().latency.avg;
    let policy = Policy {
        check_interval_ms: expected * 3.0,
        cooldown_ms: expected * 12.0,
        confirmations: 3,
        ..Policy::default()
    };
    let mut mgr = RuntimeManager::new(
        device_arc, registry_arc, lut, objective, space, initial,
    )
    .with_policy(policy);

    let mut sim = DeviceSim::new(device.clone(), Clock::sim());
    let mut points = Vec::new();
    let mut switches = Vec::new();
    let mut first_throttle_at = None;

    for i in 0..n_inferences {
        let design = mgr.current().clone();
        let v = registry.get(&design.variant).unwrap();
        let exec = sim.run_inference(
            v, design.hw.engine, design.hw.threads, design.hw.governor)?;
        if exec.thermal_scale < 1.0 && first_throttle_at.is_none() {
            first_throttle_at = Some(i);
        }
        mgr.record_latency(exec.latency_ms);

        // Middleware c: loads + thermal state.
        let mut conds = Conditions::idle();
        for e in &sim.profile.engines {
            conds.thermal.insert(e.kind, thermal_scale(&sim, e.kind));
        }
        if let Some(sw) = mgr.observe(sim.clock.now_ms(), &conds) {
            switches.push((i, sw));
        }
        points.push(ThermalPoint {
            inference: i,
            latency_ms: exec.latency_ms,
            engine: design.hw.engine,
            temp_c: exec.temp_c,
            thermal_scale: exec.thermal_scale,
        });
        // Continuous stream: no idle between frames.
    }
    Ok(Fig8Result { points, switches, initial_engine, first_throttle_at })
}

fn thermal_scale(sim: &DeviceSim, kind: EngineKind) -> f64 {
    sim.conditions().thermal_scale(kind)
}

/// Print the Fig 8 trace and summary.
pub fn print(registry: &Registry, n: u64) -> Result<()> {
    let r = run(registry, n)?;
    println!("FIG 8 — Runtime Manager under thermal throttling ({FAMILY} on {DEVICE})");
    println!("initial engine: {}", r.initial_engine.name());
    println!("{:>6} {:>11} {:<6} {:>8} {:>7}",
             "infer", "latency ms", "eng", "temp C", "fscale");
    for p in r.points.iter().step_by((n as usize / 40).max(1)) {
        println!("{:>6} {:>11.4} {:<6} {:>8.1} {:>7.2}",
                 p.inference, p.latency_ms, p.engine.name(), p.temp_c,
                 p.thermal_scale);
    }
    if let Some(t) = r.first_throttle_at {
        println!("first throttling at inference {t} (paper: after the ~85th image)");
    }
    for (i, sw) in &r.switches {
        println!(
            "  switch at inference {i}: {} -> {} (detected in {:.2} scaled-ms ≈ {} inferences)",
            sw.from.hw.engine.name(),
            sw.to.hw.engine.name(),
            sw.detection_ms,
            (sw.detection_ms
                / r.points.get(*i as usize).map(|p| p.latency_ms).unwrap_or(1.0))
                .round(),
        );
    }
    println!("(paper: NNAPI -> GPU at ~800 ms, GPU -> CPU at ~1150 ms)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_fixtures::fake_registry;

    #[test]
    fn continuous_stream_throttles_then_migrates() {
        let reg = fake_registry();
        let r = run(&reg, 800).unwrap();
        assert!(r.first_throttle_at.is_some(), "never throttled");
        assert!(!r.switches.is_empty(), "never migrated");
        // The first switch must leave the initial engine after throttling
        // began.
        let (idx, sw) = &r.switches[0];
        assert_eq!(sw.from.hw.engine, r.initial_engine);
        assert!(*idx >= r.first_throttle_at.unwrap());
    }

    #[test]
    fn latency_rises_with_throttling_before_switch() {
        let reg = fake_registry();
        let r = run(&reg, 800).unwrap();
        let first_sw = r.switches[0].0 as usize;
        let early = r.points[..10.min(first_sw)].iter()
            .map(|p| p.latency_ms).sum::<f64>() / 10.0_f64.min(first_sw as f64);
        let just_before = &r.points[first_sw.saturating_sub(1)];
        assert!(just_before.latency_ms > early,
                "latency should degrade before the switch");
    }

    #[test]
    fn migration_chain_reaches_multiple_engines() {
        let reg = fake_registry();
        let r = run(&reg, 3000).unwrap();
        let engines: std::collections::BTreeSet<_> =
            r.points.iter().map(|p| p.engine).collect();
        assert!(engines.len() >= 2, "expected multi-engine chain: {engines:?}");
    }
}
