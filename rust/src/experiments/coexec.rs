//! Intra-model co-execution benchmark (`oodin opt-bench --coexec`):
//! quantifies what pipelined multi-engine partitioning buys over the best
//! monolithic deployment of the same family.
//!
//! The σ-space is widened with partitioned execution plans
//! ([`crate::measurements::partition_plans`]): every batch-1 variant is
//! additionally measured under every 2- and 3-segment engine pipeline of
//! the device at the default cut grid, and the frontier machinery trades
//! those plans against the historical monolithic designs under the same
//! memory / availability / ε-accuracy filters.  For each app of the
//! canonical mix the driver replays two condition events (idle, a CPU load
//! burst), asserts frontier-walk vs full-search exactness on the widened
//! space, validates the idle selection against a zero-noise
//! [`DeviceSim`] pipelined execution, and reports the partitioned-vs-
//! monolithic speedup.  The smoke LUT is measured with zero sampling
//! noise, so the whole report is closed-form from the roofline model and
//! golden-pinned (`tests/golden/coexec_smoke.json`), regenerated
//! independently by `python/golden_optbench.py`.

use anyhow::{ensure, Context, Result};

use std::sync::Arc;

use crate::designspace::{rank, ConditionsBucket, DesignSpace, FrontierCache};
use crate::device::EngineKind;
use crate::devicesim::DeviceSim;
use crate::manager::{design_id, Conditions};
use crate::mdcl;
use crate::measurements::{ExecPlan, Measurer};
use crate::model::Registry;
use crate::optimizer::SearchSpace;
use crate::perf;
use crate::telemetry::trace::{round3, FlightRecorder, TraceEvent};
use crate::util::clock::Clock;
use crate::util::json::{self, Value};

use super::optbench::{canonical_mix, objective_label};
use super::r3;

/// Device the golden-pinned smoke runs on (the mid-tier Table I profile —
/// the only one with all three engines *and* headroom for 3-segment
/// pipelines).
pub const SMOKE_DEVICE: &str = "samsung_a71";

/// Measurement runs for the smoke LUT (warmup = 1, like `opt-bench`).
pub const SMOKE_LUT_RUNS: usize = 8;

/// Byte budget for one app's frontier cache.  The two smoke buckets of the
/// widened (partition-bearing) space sit well below it — the co-exec
/// report pins no cache-accounting fields, so this only has to be
/// comfortable, not tight.
pub const COEXEC_CACHE_BUDGET_BYTES: u64 = 1024 * 1024;

/// The replayed condition events: idle, then a CPU load burst (bucket
/// centre `2^2`) that pushes pipelines off their CPU segments.
pub fn event_sequence() -> Vec<(&'static str, Conditions)> {
    let idle = Conditions::idle();
    let mut cpu = Conditions::idle();
    cpu.loads.insert(EngineKind::Cpu, 2.0);
    vec![("idle", idle), ("cpu_load", cpu)]
}

/// One condition event's decision record.
#[derive(Debug, Clone)]
pub struct CoexecEventRow {
    /// Event label.
    pub name: &'static str,
    /// Conditions-bucket id the event landed in.
    pub bucket: String,
    /// Candidates a full search scores at this event (widened space).
    pub full_evals: usize,
    /// Candidates the frontier walk scores at this event.
    pub frontier_evals: usize,
    /// True when this event built the bucket's frontier (first visit).
    pub built: bool,
    /// True when both selections agree (must always hold).
    pub selections_match: bool,
    /// The selected design, `variant|engine-or-plan|threads|governor|r=..`.
    pub pick: String,
    /// Adjusted latency of the selection at the bucket's representative
    /// conditions (ms).
    pub latency_ms: f64,
    /// True when the selection is a partitioned plan.
    pub partitioned: bool,
}

/// One app row of the co-execution report.
#[derive(Debug, Clone)]
pub struct CoexecRow {
    /// Device profile name.
    pub device: String,
    /// App id from the canonical mix.
    pub app: &'static str,
    /// Model family the app is built around.
    pub family: &'static str,
    /// Objective label.
    pub objective: String,
    /// Widened-space size (monolithic + partitioned) at the idle bucket.
    pub space_size: usize,
    /// Monolithic candidates within that space.
    pub mono_space_size: usize,
    /// Frontier size at the idle bucket.
    pub frontier_size_idle: usize,
    /// Per-event decision records.
    pub events: Vec<CoexecEventRow>,
    /// Best monolithic design at idle (the pre-partitioning optimum).
    pub best_mono: String,
    /// Its condition-adjusted average latency at idle (ms, un-rounded).
    pub best_mono_avg_ms: f64,
    /// The idle selection over the widened space.
    pub pick: String,
    /// Its condition-adjusted average latency at idle (ms, un-rounded).
    pub pick_avg_ms: f64,
    /// `best_mono_avg_ms / pick_avg_ms` (un-rounded; the CI gate compares
    /// this raw value against the pinned 1.2× margin).
    pub speedup_vs_mono: f64,
    /// True when the idle selection is a partitioned plan.
    pub partitioned_pick: bool,
    /// True when a zero-noise [`DeviceSim`] execution of the idle
    /// selection reproduced its LUT latency to 1e-9 ms.
    pub sim_matches: bool,
}

/// The complete co-execution report.
#[derive(Debug, Clone)]
pub struct CoexecReport {
    /// Device profile name.
    pub device: String,
    /// Partitioned keys the widened LUT carries.
    pub split_keys: usize,
    /// Per-app rows.
    pub rows: Vec<CoexecRow>,
}

/// Run one app's co-execution replay over the widened LUT.
fn run_app(device: &crate::device::DeviceProfile, registry: &Registry,
           lut: &crate::measurements::Lut, app: &'static str,
           family: &'static str, objective: crate::optimizer::Objective,
           recorder: Option<&Arc<FlightRecorder>>) -> Result<CoexecRow> {
    let space = DesignSpace::new(device, registry, lut);
    let sspace = SearchSpace::family(family);
    let mut cache =
        FrontierCache::new().with_mem_budget(COEXEC_CACHE_BUDGET_BYTES);
    if let Some(rec) = recorder {
        cache.set_recorder(Arc::clone(rec), app);
    }

    let mut events = Vec::new();
    let mut idle_pick = None;
    let mut space_size = 0usize;
    let mut mono_space_size = 0usize;
    let mut frontier_size_idle = 0usize;

    for (i, (name, conds)) in event_sequence().into_iter().enumerate() {
        if let Some(rec) = recorder {
            rec.set_now_us(i as u64 * 1_000);
        }
        let bucket = ConditionsBucket::of(&conds);
        let rep = bucket.representative();

        // Full search over the widened (mono + partitioned) space.
        let cands = space.enumerate(objective, &sspace, &rep);
        let n_mono = cands
            .iter()
            .filter(|c| c.design.hw.plan == ExecPlan::Mono)
            .count();
        let full = rank(cands, objective);
        let full_pick = full.first().with_context(|| {
            format!("{app}: no feasible design at {}", bucket.id())
        })?;

        // Frontier walk, cached per bucket.
        let builds_before = cache.stats.builds;
        let frontier = cache.frontier(&space, objective, &sspace, &bucket);
        let built = cache.stats.builds > builds_before;
        ensure!(frontier.len() < full.len(),
                "{app}@{name}: frontier ({}) must stay strictly below the \
                 widened space ({})",
                frontier.len(), full.len());
        let pick = frontier.best().with_context(|| {
            format!("{app}: empty frontier at {}", bucket.id())
        })?;
        let selections_match = pick.design == full_pick.design;
        ensure!(selections_match,
                "{app}@{name}: frontier pick {} != full-search pick {}",
                design_id(&pick.design), design_id(&full_pick.design));

        if bucket.is_idle() {
            idle_pick = Some(pick.clone());
            space_size = full.len();
            mono_space_size = n_mono;
            frontier_size_idle = frontier.len();
        }
        events.push(CoexecEventRow {
            name,
            bucket: bucket.id(),
            full_evals: full.len(),
            frontier_evals: frontier.len(),
            built,
            selections_match,
            pick: design_id(&pick.design),
            latency_ms: r3(pick.latency_ms),
            partitioned: pick.design.hw.plan.is_split(),
        });
    }

    let idle_pick = idle_pick
        .with_context(|| format!("{app}: event sequence has no idle event"))?;

    // The pre-partitioning optimum: best monolithic design at idle.
    let mono_cands = space.enumerate_where(objective, &sspace,
                                           &Conditions::idle(),
                                           |k| k.plan == ExecPlan::Mono);
    let mono = rank(mono_cands, objective)
        .into_iter()
        .next()
        .with_context(|| format!("{app}: no feasible monolithic design"))?;
    let speedup = mono.avg_latency_ms / idle_pick.avg_latency_ms;
    let partitioned_pick = idle_pick.design.hw.plan.is_split();

    // Validate the idle selection against a fresh zero-noise device
    // simulation: the pipelined (or monolithic) execution path must
    // reproduce the LUT's closed-form latency.
    let variant = registry.get(&idle_pick.design.variant).with_context(|| {
        format!("{app}: unknown variant {}", idle_pick.design.variant)
    })?;
    let entry = lut.get(&idle_pick.design.lut_key()).with_context(|| {
        format!("{app}: pick {} missing from LUT",
                design_id(&idle_pick.design))
    })?;
    let mut sim = DeviceSim::new(device.clone(), Clock::sim());
    sim.set_noise_sigma(0.0);
    let simmed = match &idle_pick.design.hw.plan {
        ExecPlan::Mono => sim.run_inference(variant,
                                            idle_pick.design.hw.engine,
                                            idle_pick.design.hw.threads,
                                            idle_pick.design.hw.governor)?,
        ExecPlan::Split(p) => sim.run_pipelined(variant, &p.engines,
                                                &p.cuts_pm,
                                                idle_pick.design.hw.governor)?,
    };
    let sim_matches = (simmed.latency_ms - entry.latency.avg).abs() <= 1e-9;
    ensure!(sim_matches,
            "{app}: device-sim latency {} != LUT latency {} for {}",
            simmed.latency_ms, entry.latency.avg,
            design_id(&idle_pick.design));

    if let (Some(rec), ExecPlan::Split(p)) =
        (recorder, &idle_pick.design.hw.plan)
    {
        rec.emit(TraceEvent::Partition {
            scope: app.to_string(),
            design: design_id(&idle_pick.design),
            stages: p.engines.len() as u64,
            latency_ms: round3(idle_pick.avg_latency_ms),
            speedup: round3(speedup),
        });
    }

    Ok(CoexecRow {
        device: device.name.to_string(),
        app,
        family,
        objective: objective_label(objective),
        space_size,
        mono_space_size,
        frontier_size_idle,
        events,
        best_mono: design_id(&mono.design),
        best_mono_avg_ms: mono.avg_latency_ms,
        pick: design_id(&idle_pick.design),
        pick_avg_ms: idle_pick.avg_latency_ms,
        speedup_vs_mono: speedup,
        partitioned_pick,
        sim_matches,
    })
}

/// Run the golden-pinned co-execution smoke.
pub fn run(registry: &Registry) -> Result<CoexecReport> {
    run_traced(registry, None)
}

/// [`run`] with an optional flight recorder: frontier-cache transitions
/// plus one `partition` adaptation event per partitioned selection.
pub fn run_traced(registry: &Registry,
                  recorder: Option<&Arc<FlightRecorder>>)
                  -> Result<CoexecReport> {
    let device = mdcl::detect(SMOKE_DEVICE)?;
    let lut = Measurer::new(&device, registry)
        .with_runs(SMOKE_LUT_RUNS, (SMOKE_LUT_RUNS / 10).max(1))
        .with_noise_sigma(0.0)
        .measure_with_partitions()?;
    let split_keys =
        lut.entries.keys().filter(|k| k.plan.is_split()).count();
    let mut rows = Vec::new();
    for (app, family, objective) in canonical_mix(4) {
        rows.push(run_app(&device, registry, &lut, app, family, objective,
                          recorder)?);
    }
    // The headline acceptance gate: at least one app must deploy a
    // partitioned plan that beats its best monolithic design by the
    // pinned margin (compared on raw, un-rounded speedups).
    ensure!(rows.iter().any(|r| r.partitioned_pick
                                && r.speedup_vs_mono >= 1.2),
            "no app picked a partitioned plan with >= 1.2x speedup");
    Ok(CoexecReport { device: device.name.to_string(), split_keys, rows })
}

/// The complete report as one JSON value (the golden-pinned payload).
pub fn report_json(report: &CoexecReport) -> Value {
    let rows = report
        .rows
        .iter()
        .map(|r| {
            let events = r
                .events
                .iter()
                .map(|e| {
                    json::obj(vec![
                        ("name", json::s(e.name)),
                        ("bucket", json::s(&e.bucket)),
                        ("full_evals", json::num(e.full_evals as f64)),
                        ("frontier_evals",
                         json::num(e.frontier_evals as f64)),
                        ("built", Value::Bool(e.built)),
                        ("match", Value::Bool(e.selections_match)),
                        ("pick", json::s(&e.pick)),
                        ("latency_ms", json::num(e.latency_ms)),
                        ("partitioned", Value::Bool(e.partitioned)),
                    ])
                })
                .collect();
            json::obj(vec![
                ("device", json::s(&r.device)),
                ("app", json::s(r.app)),
                ("family", json::s(r.family)),
                ("objective", json::s(&r.objective)),
                ("space_size", json::num(r.space_size as f64)),
                ("mono_space_size", json::num(r.mono_space_size as f64)),
                ("frontier_size_idle",
                 json::num(r.frontier_size_idle as f64)),
                ("events", Value::Arr(events)),
                ("best_mono", json::s(&r.best_mono)),
                ("best_mono_avg_ms", json::num(r3(r.best_mono_avg_ms))),
                ("pick", json::s(&r.pick)),
                ("pick_avg_ms", json::num(r3(r.pick_avg_ms))),
                ("speedup_vs_mono", json::num(r3(r.speedup_vs_mono))),
                ("partitioned_pick", Value::Bool(r.partitioned_pick)),
                ("sim_matches", Value::Bool(r.sim_matches)),
            ])
        })
        .collect();
    json::obj(vec![(
        "coexec",
        json::obj(vec![
            ("device", json::s(&report.device)),
            ("lut_runs", json::num(SMOKE_LUT_RUNS as f64)),
            ("noise_sigma", json::num(0.0)),
            ("handoff_ms", json::num(perf::HANDOFF_MS)),
            ("split_keys", json::num(report.split_keys as f64)),
            ("rows", Value::Arr(rows)),
        ]),
    )])
}

/// Print the partitioned-vs-monolithic table; also emit the report as a
/// JSON line and, when `json_out` is given, write it to that file.  With
/// `trace_out`, the run is flight-recorded and exported as JSON-lines at
/// that path plus Chrome trace-event JSON at `<trace_out>.chrome.json`.
pub fn print(registry: &Registry, json_out: Option<&str>,
             trace_out: Option<&str>) -> Result<()> {
    let recorder = trace_out.map(|_| Arc::new(FlightRecorder::new()));
    let report = run_traced(registry, recorder.as_ref())?;
    println!("CO-EXEC — pipelined multi-engine partitioning vs best \
              monolithic deployment ({} partitioned LUT keys)",
             report.split_keys);
    println!("{:<16} {:>5} {:>5} {:>5} | {:<34} {:>8} | {:>8} {:>7}",
             "app", "space", "mono", "front", "idle pick", "avg ms",
             "mono ms", "speedup");
    println!("{}", super::rule(100));
    for r in &report.rows {
        println!("{:<16} {:>5} {:>5} {:>5} | {:<34} {:>8.3} | {:>8.3} \
                  {:>6.2}x",
                 r.app, r.space_size, r.mono_space_size,
                 r.frontier_size_idle, r.pick, r.pick_avg_ms,
                 r.best_mono_avg_ms, r.speedup_vs_mono);
    }
    println!("(space = widened σ-space at idle; mono = monolithic subset; \
              front = idle-bucket frontier; picks verified against full \
              search on every event and against a zero-noise device-sim \
              execution)");
    if let (Some(path), Some(rec)) = (trace_out, &recorder) {
        std::fs::write(path, rec.to_jsonl())
            .with_context(|| format!("writing {path}"))?;
        let chrome = format!("{path}.chrome.json");
        std::fs::write(&chrome, rec.to_chrome_trace())
            .with_context(|| format!("writing {chrome}"))?;
        println!("trace: {} events ({} dropped) to {path}; Chrome trace \
                  to {chrome}",
                 rec.len(), rec.dropped());
    }
    let line = json::to_string(&report_json(&report));
    println!("COEXEC_JSON {line}");
    if let Some(path) = json_out {
        std::fs::write(path, &line)
            .with_context(|| format!("writing {path}"))?;
        println!("JSON written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_fixtures::fake_registry;

    #[test]
    fn smoke_meets_the_coexec_gate() {
        let reg = fake_registry();
        let report = run(&reg).unwrap();
        assert_eq!(report.rows.len(), 4, "all four apps deployable");
        assert!(report.split_keys > 0);
        let mut winners = 0;
        for r in &report.rows {
            assert!(r.mono_space_size < r.space_size, "{r:?}");
            assert!(r.sim_matches, "{r:?}");
            assert!(r.speedup_vs_mono >= 1.0 - 1e-12, "{r:?}");
            for e in &r.events {
                assert!(e.selections_match, "{e:?}");
                assert!(e.frontier_evals < e.full_evals, "{e:?}");
            }
            if r.partitioned_pick && r.speedup_vs_mono >= 1.2 {
                winners += 1;
            }
        }
        assert!(winners >= 1, "gate: no partitioned win >= 1.2x");
    }

    #[test]
    fn partition_trace_events_are_emitted() {
        let reg = fake_registry();
        let rec = Arc::new(FlightRecorder::new());
        let report = run_traced(&reg, Some(&rec)).unwrap();
        let jsonl = rec.to_jsonl();
        let partitioned =
            report.rows.iter().filter(|r| r.partitioned_pick).count();
        assert!(partitioned >= 1);
        assert_eq!(jsonl.matches("\"ev\":\"partition\"").count(),
                   partitioned);
    }
}
