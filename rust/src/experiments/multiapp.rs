//! Multi-app contention experiment: 1–4 concurrent DL apps × the three
//! Table I device profiles.
//!
//! For each cell three hostings are compared over the same simulated
//! device:
//!
//! * **isolation** — each app alone with its solo-optimal design (the
//!   per-app latency floor the SLOs are derived from);
//! * **shared (joint)** — the `scheduler` subsystem: joint σ-vector
//!   search, time-sliced engine arbitration, admission control and
//!   coordinated re-adaptation when conditions shift mid-run;
//! * **naive-independent** — every app independently picks (and greedily
//!   re-picks, with no coordination, hysteresis or cooldown) its own best
//!   design as if it owned the device; co-located apps then contend on
//!   their common engine, which the device sim models as a latency
//!   multiplier equal to the number of sharers.
//!
//! Prints the contention table and emits the same rows as JSON (stdout
//! line + optional file) so future BENCH_*.json runs can track it.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::app::multi_scenario;
use crate::device::{DeviceProfile, EngineKind};
use crate::devicesim::DeviceSim;
use crate::manager::RuntimeManager;
use crate::mdcl;
use crate::measurements::{Lut, Measurer};
use crate::model::Registry;
use crate::optimizer::{Design, Optimizer, SearchSpace};
use crate::scheduler::{Admission, Scheduler, WorkloadDescriptor};
use crate::util::clock::Clock;
use crate::util::json::{self, Value};

/// Experiment dimensions and depth.
#[derive(Debug, Clone)]
pub struct MultiAppConfig {
    /// Device profiles to sweep.
    pub devices: Vec<String>,
    /// Concurrency levels to sweep (apps per cell).
    pub app_counts: Vec<usize>,
    /// Arbitration windows simulated per hosting.
    pub windows: usize,
    /// Measurement runs for the per-device LUT.
    pub lut_runs: usize,
    /// SLO bound = `slo_factor` × each app's solo-optimal latency.
    pub slo_factor: f64,
    /// External load injected on the busiest engine halfway through.
    pub load_shift: f64,
}

impl MultiAppConfig {
    /// The full contention table: 1–4 apps × all three device profiles.
    pub fn full() -> Self {
        MultiAppConfig {
            devices: vec!["sony_c5".into(), "samsung_a71".into(),
                          "samsung_s20_fe".into()],
            app_counts: vec![1, 2, 3, 4],
            windows: 16,
            lut_runs: 120,
            slo_factor: 1.8,
            load_shift: 1.2,
        }
    }

    /// A CI-sized smoke run exercising the whole subsystem end-to-end.
    pub fn smoke() -> Self {
        MultiAppConfig {
            devices: vec!["samsung_a71".into()],
            app_counts: vec![1, 3],
            windows: 6,
            lut_runs: 16,
            slo_factor: 1.8,
            load_shift: 1.2,
        }
    }
}

/// One (device, app-count) cell of the contention table.
#[derive(Debug, Clone)]
pub struct Cell {
    /// Device profile name.
    pub device: String,
    /// Requested concurrency (apps actually available on the device may be
    /// fewer: admitted + rejected).
    pub n_apps: usize,
    /// Apps the joint scheduler admitted.
    pub admitted: usize,
    /// Apps admission control rejected.
    pub rejected: usize,
    /// Admitted apps running degraded to fit the budget.
    pub degraded: usize,
    /// Mean solo-optimal latency across the hosted apps (ms).
    pub isolation_ms: f64,
    /// Mean latency under the joint scheduler (ms).
    pub joint_ms: f64,
    /// Mean latency under naive-independent hosting (ms).
    pub naive_ms: f64,
    /// SLO-violation share under the joint scheduler.
    pub joint_viol_rate: f64,
    /// SLO-violation share under naive-independent hosting.
    pub naive_viol_rate: f64,
    /// Reconfigurations the joint scheduler issued.
    pub joint_switches: usize,
    /// Reconfigurations the naive managers issued.
    pub naive_switches: usize,
}

/// Engine hosting the most apps (ties resolved by `EngineKind` order,
/// last wins) — where the mid-run external load is injected.
fn busiest_engine(designs: &[Design]) -> EngineKind {
    let mut counts: BTreeMap<EngineKind, usize> = BTreeMap::new();
    for d in designs {
        *counts.entry(d.hw.engine).or_insert(0) += 1;
    }
    counts
        .into_iter()
        .max_by_key(|&(_, n)| n)
        .map(|(e, _)| e)
        .unwrap_or(EngineKind::Cpu)
}

/// Measure the per-device LUT once (shared by every cell of that device).
pub fn device_lut(registry: &Registry, device: &DeviceProfile,
                  cfg: &MultiAppConfig) -> Result<Arc<Lut>> {
    Ok(Arc::new(
        Measurer::new(device, registry)
            .with_runs(cfg.lut_runs, (cfg.lut_runs / 10).max(1))
            .measure_all()?,
    ))
}

/// Run one cell: scenario, then the joint and naive hostings.  The naive
/// baseline serves exactly the apps the joint scheduler admitted, so both
/// violation rates cover identical traffic.  `None` when the device can
/// host none of the scenario's apps.
pub fn run_cell(registry: &Registry, device: &DeviceProfile, lut: &Arc<Lut>,
                n_apps: usize, cfg: &MultiAppConfig) -> Result<Option<Cell>> {
    let descs = multi_scenario(n_apps, device, registry, lut, cfg.slo_factor);
    if descs.is_empty() {
        return Ok(None);
    }

    // ---- shared (joint) hosting -----------------------------------------
    let mut sched = Scheduler::new(Arc::new(device.clone()),
                                   Arc::new(registry.clone()),
                                   Arc::clone(lut));
    let mut sim = DeviceSim::new(device.clone(), Clock::sim());
    let mut hosted: Vec<WorkloadDescriptor> = Vec::new();
    let mut rejected = 0usize;
    for d in &descs {
        match sched.register(d.clone(), sim.clock.now_ms(),
                             &sim.conditions())? {
            Admission::Admitted { .. } => hosted.push(d.clone()),
            Admission::Rejected { .. } => rejected += 1,
        }
    }
    if sched.is_empty() {
        return Ok(None);
    }
    let admitted = hosted.len();
    let isolation_ms = hosted
        .iter()
        .map(|d| d.slo_latency_ms / cfg.slo_factor)
        .sum::<f64>()
        / hosted.len() as f64;
    let degraded = sched.degraded_ids().len();
    let switches_base = sched.switches.len();
    let joint_designs: Vec<Design> =
        sched.designs().into_iter().map(|(_, d)| d).collect();
    let shift_engine = busiest_engine(&joint_designs);

    let mut joint_inf = 0u64;
    let mut joint_viol = 0u64;
    let mut joint_sum_ms = 0.0;
    for w in 0..cfg.windows {
        if w == cfg.windows / 2 {
            sim.set_load(shift_engine, cfg.load_shift);
        }
        let rep = sched.run_window(&mut sim)?;
        for a in &rep.apps {
            joint_inf += a.inferences;
            joint_viol += a.violations;
            joint_sum_ms += a.mean_latency_ms * a.inferences as f64;
        }
        sched.observe(sim.clock.now_ms(), &sim.conditions());
    }
    let joint_switches = sched.switches.len() - switches_base;

    // ---- naive-independent hosting (same admitted apps) ------------------
    // Each app gets its own RuntimeManager and greedily follows
    // `best_under` every window — no coordination, hysteresis or cooldown:
    // exactly what N independent managers would do.
    let mut sim = DeviceSim::new(device.clone(), Clock::sim());
    let dev_arc = Arc::new(device.clone());
    let reg_arc = Arc::new(registry.clone());
    let mut naive: Vec<(WorkloadDescriptor, Design, RuntimeManager)> =
        Vec::new();
    for d in &hosted {
        let opt = Optimizer::new(device, registry, lut);
        let init = opt
            .optimize(d.objective, &SearchSpace::family(&d.family))
            .context("naive solo optimisation")?
            .design;
        let mgr = RuntimeManager::new(
            Arc::clone(&dev_arc),
            Arc::clone(&reg_arc),
            Arc::clone(lut),
            d.objective,
            SearchSpace::family(&d.family),
            init.clone(),
        );
        naive.push((d.clone(), init, mgr));
    }
    let slices = sched.arbiter.slices_per_window.max(naive.len());
    let total_fps: f64 = naive.iter().map(|(d, _, _)| d.arrival_fps).sum();
    let mut ext: BTreeMap<EngineKind, f64> = BTreeMap::new();
    let mut naive_inf = 0u64;
    let mut naive_viol = 0u64;
    let mut naive_sum_ms = 0.0;
    let mut naive_switches = 0usize;
    for w in 0..cfg.windows {
        if w == cfg.windows / 2 {
            let designs: Vec<Design> =
                naive.iter().map(|(_, d, _)| d.clone()).collect();
            ext.insert(busiest_engine(&designs), cfg.load_shift);
        }
        // Perceived per-engine load: external + co-runner sharing (k apps
        // on one engine => each sees a k-fold latency multiplier).
        let mut sharers: BTreeMap<EngineKind, usize> = BTreeMap::new();
        for (_, d, _) in &naive {
            *sharers.entry(d.hw.engine).or_insert(0) += 1;
        }
        for e in EngineKind::ALL {
            if !device.has_engine(e) {
                continue;
            }
            let k = sharers.get(&e).copied().unwrap_or(0).max(1) as f64;
            sim.set_load(e, ext.get(&e).copied().unwrap_or(0.0) + k.log2());
        }
        for (d, design, _) in &naive {
            let grants = ((slices as f64 * d.arrival_fps / total_fps.max(1e-9))
                .floor() as usize)
                .max(1);
            let v = registry
                .get(&design.variant)
                .context("naive variant not in registry")?
                .clone();
            for _ in 0..grants {
                let exec = sim.run_inference(&v, design.hw.engine,
                                             design.hw.threads,
                                             design.hw.governor)?;
                naive_inf += 1;
                if exec.latency_ms > d.slo_latency_ms {
                    naive_viol += 1;
                }
                naive_sum_ms += exec.latency_ms;
            }
        }
        // Greedy, uncoordinated re-pick under the perceived conditions.
        let conds = sim.conditions();
        for (_, design, mgr) in naive.iter_mut() {
            if let Ok(b) = mgr.best_under(&conds) {
                if b != *design {
                    naive_switches += 1;
                    *design = b;
                }
            }
        }
    }

    Ok(Some(Cell {
        device: device.name.to_string(),
        n_apps,
        admitted,
        rejected,
        degraded,
        isolation_ms,
        joint_ms: joint_sum_ms / joint_inf.max(1) as f64,
        naive_ms: naive_sum_ms / naive_inf.max(1) as f64,
        joint_viol_rate: joint_viol as f64 / joint_inf.max(1) as f64,
        naive_viol_rate: naive_viol as f64 / naive_inf.max(1) as f64,
        joint_switches,
        naive_switches,
    }))
}

/// Run every (device, app-count) cell of the contention table.
pub fn run(registry: &Registry, cfg: &MultiAppConfig) -> Result<Vec<Cell>> {
    let mut cells = Vec::new();
    for device_name in &cfg.devices {
        let device = mdcl::detect(device_name)?;
        // One measurement sweep per device, shared by all its cells.
        let lut = device_lut(registry, &device, cfg)?;
        for &n in &cfg.app_counts {
            if let Some(cell) = run_cell(registry, &device, &lut, n, cfg)? {
                cells.push(cell);
            }
        }
    }
    Ok(cells)
}

fn cells_to_json(cells: &[Cell]) -> Value {
    Value::Arr(
        cells
            .iter()
            .map(|c| {
                json::obj(vec![
                    ("device", json::s(&c.device)),
                    ("n_apps", json::num(c.n_apps as f64)),
                    ("admitted", json::num(c.admitted as f64)),
                    ("rejected", json::num(c.rejected as f64)),
                    ("degraded", json::num(c.degraded as f64)),
                    ("isolation_ms", json::num(c.isolation_ms)),
                    ("joint_ms", json::num(c.joint_ms)),
                    ("naive_ms", json::num(c.naive_ms)),
                    ("joint_viol_rate", json::num(c.joint_viol_rate)),
                    ("naive_viol_rate", json::num(c.naive_viol_rate)),
                    ("joint_switches", json::num(c.joint_switches as f64)),
                    ("naive_switches", json::num(c.naive_switches as f64)),
                ])
            })
            .collect(),
    )
}

/// Print the contention table; also emit the rows as a JSON line and,
/// when `json_out` is given, write them to that file.
pub fn print(registry: &Registry, cfg: &MultiAppConfig,
             json_out: Option<&str>) -> Result<()> {
    let cells = run(registry, cfg)?;
    println!("MULTI-APP — contention table \
              (shared joint scheduler vs naive-independent hosting)");
    println!("{:<15} {:>4} {:>4} {:>4} {:>4} | {:>9} | {:>9} {:>6} {:>3} \
              | {:>9} {:>6} {:>3}",
             "device", "apps", "adm", "rej", "deg", "iso ms",
             "joint ms", "viol%", "sw", "naive ms", "viol%", "sw");
    println!("{}", super::rule(92));
    for c in &cells {
        println!("{:<15} {:>4} {:>4} {:>4} {:>4} | {:>9.4} | {:>9.4} \
                  {:>6.1} {:>3} | {:>9.4} {:>6.1} {:>3}",
                 c.device, c.n_apps, c.admitted, c.rejected, c.degraded,
                 c.isolation_ms, c.joint_ms, c.joint_viol_rate * 100.0,
                 c.joint_switches, c.naive_ms, c.naive_viol_rate * 100.0,
                 c.naive_switches);
    }
    println!("(viol% = share of inferences missing the app's SLO; \
              sw = reconfigurations issued)");
    let payload = json::obj(vec![("multiapp", cells_to_json(&cells))]);
    let line = json::to_string(&payload);
    println!("MULTIAPP_JSON {line}");
    if let Some(path) = json_out {
        std::fs::write(path, &line)
            .with_context(|| format!("writing {path}"))?;
        println!("JSON written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_fixtures::fake_registry;

    fn a71_cell(n_apps: usize) -> Cell {
        let reg = fake_registry();
        let cfg = MultiAppConfig::smoke();
        let dev = mdcl::detect("samsung_a71").unwrap();
        let lut = device_lut(&reg, &dev, &cfg).unwrap();
        run_cell(&reg, &dev, &lut, n_apps, &cfg).unwrap().unwrap()
    }

    #[test]
    fn joint_beats_naive_under_contention_on_a71() {
        // The pinned contention scenario: three apps on the Samsung A71.
        // Naive-independent hosting herds the classification apps onto the
        // NPU (each sees a k-fold slowdown); the joint scheduler spreads
        // them across CPU/GPU/NPU and must achieve a strictly lower
        // SLO-violation rate over the same admitted traffic.
        let cell = a71_cell(3);
        assert_eq!(cell.admitted, 3);
        assert_eq!(cell.rejected, 0);
        assert!(cell.naive_viol_rate > 0.0,
                "naive hosting shows no contention: {cell:?}");
        assert!(cell.joint_viol_rate < cell.naive_viol_rate,
                "joint {} !< naive {}", cell.joint_viol_rate,
                cell.naive_viol_rate);
    }

    #[test]
    fn single_app_cell_matches_isolation() {
        let cell = a71_cell(1);
        assert_eq!(cell.admitted, 1);
        // Alone on the device, the scheduler's latency stays close to the
        // isolation floor before the load shift (same design, same sim).
        assert!(cell.joint_ms < cell.isolation_ms * 4.0, "{cell:?}");
        assert!(cell.joint_viol_rate <= 0.5, "{cell:?}");
    }

    #[test]
    fn smoke_table_runs_end_to_end() {
        let reg = fake_registry();
        let cells = run(&reg, &MultiAppConfig::smoke()).unwrap();
        assert!(!cells.is_empty());
        for c in &cells {
            assert!(c.admitted + c.rejected >= 1);
            assert!(c.joint_ms > 0.0 && c.naive_ms > 0.0);
        }
    }
}
