//! Fig 4/5/6: OODIn vs platform-aware (PAW-D) and model-aware (MAW-D)
//! designs on the low- / mid- / high-end device respectively.
//!
//! Objective (paper): minimise the 90th-percentile latency, no accuracy
//! drop (ε per `EVAL_EPSILON`).
//!
//! * **PAW-D** — model-unaware: the configuration (t, hw) is optimised for
//!   the proxy DNN EfficientNetLite4 on the *target device*, then reused
//!   across models on that device.
//! * **MAW-D** — platform-agnostic: the configuration is optimised for the
//!   *target model* on the flagship S20 FE (industry practice), then reused
//!   across devices.  When the S20-chosen engine is absent on the target
//!   (Sony has no NPU), NNAPI falls back to single-thread CPU — as the real
//!   NNAPI reference implementation does.
//!
//! Models whose best sustained latency exceeds the device's deployability
//! bound (or that do not fit memory) are reported as not deployable — the
//! paper drops those bars for the Sony C5 (overheating / >= 5 s lag).

use anyhow::Result;

use crate::device::{profiles, DeviceProfile, EngineKind};
use crate::experiments::{build_lut, EVAL_EPSILON};
use crate::model::Registry;
use crate::optimizer::{Design, HwConfig, Objective, Optimizer, SearchSpace};
use crate::util::stats::{geomean, Percentile};

/// Family standing in for the paper's EfficientNet PAW/MAW study.
pub const PROXY_FAMILY: &str = "efficientnet_lite4";
/// Device the MAW baseline was "tuned on".
pub const FLAGSHIP: &str = "samsung_s20_fe";

const OBJ: Objective = Objective::MinLatency {
    stat: Percentile::P90,
    epsilon: EVAL_EPSILON,
};

/// One (device, family) comparison row of the Fig 4/5/6 study.
#[derive(Debug, Clone)]
pub struct Fig456Row {
    /// Device profile name.
    pub device: String,
    /// Model family compared.
    pub family: String,
    /// None = not deployable under that design.
    pub oodin_ms: Option<f64>,
    /// Platform-aware baseline latency (ms); None = undeployable.
    pub paw_ms: Option<f64>,
    /// Model-aware (flagship-tuned) baseline latency (ms).
    pub maw_ms: Option<f64>,
}

/// Per-device aggregates over the Fig 4/5/6 rows.
#[derive(Debug, Clone)]
pub struct Fig456Summary {
    /// Device profile name.
    pub device: String,
    /// (geo-mean, max) speedup over PAW-D.
    pub vs_paw: Option<(f64, f64)>,
    /// (geo-mean, max) speedup over MAW-D.
    pub vs_maw: Option<(f64, f64)>,
    /// Families no baseline could deploy on this device.
    pub undeployable: Vec<String>,
}

/// Map a design to the target device, applying the NNAPI->CPU(1 thread)
/// fallback when the engine is missing (real NNAPI behaviour).
fn transplant(dev: &DeviceProfile, d: &Design) -> Design {
    let mut out = d.clone();
    if !dev.has_engine(out.hw.engine) {
        out.hw = HwConfig {
            engine: EngineKind::Cpu,
            threads: 1,
            governor: out.hw.governor,
            recognition_rate: out.hw.recognition_rate,
            plan: crate::measurements::ExecPlan::Mono,
        };
    }
    // Clamp governor to ones the device exposes.
    if !dev.governors.contains(&out.hw.governor) {
        out.hw.governor = dev.governors[0];
    }
    out
}

/// Evaluate a transplanted design on a device's LUT; None when the variant
/// itself is not deployable there (memory / latency bound).
fn eval_on(opt: &Optimizer, dev: &DeviceProfile, reg: &Registry, d: &Design)
           -> Option<f64> {
    let v = reg.get(&d.variant)?;
    if !crate::perf::fits_memory(dev, v) {
        return None;
    }
    let e = opt.evaluate(d, Percentile::P90).ok()?;
    if e.avg_latency_ms > dev.max_deployable_latency_ms {
        return None;
    }
    Some(e.latency_ms)
}

/// PAW-D configuration for a device: optimise the proxy model there, keep
/// (precision, hw) and swap the family in.
fn paw_design(opt: &Optimizer, reg: &Registry, family: &str) -> Option<Design> {
    let proxy = opt.optimize(OBJ, &SearchSpace::family(PROXY_FAMILY)).ok()?;
    let proxy_v = reg.get(&proxy.design.variant)?;
    let target = reg.find(family, proxy_v.precision, 1)?;
    Some(Design { variant: target.name.clone(), hw: proxy.design.hw })
}

/// Compute every (device, family) row and the per-device summaries.
pub fn run(registry: &Registry) -> Result<(Vec<Fig456Row>, Vec<Fig456Summary>)> {
    // MAW-D source: per-family optimum on the flagship.
    let s20 = profiles::by_name(FLAGSHIP).unwrap();
    let s20_lut = build_lut(&s20, registry)?;
    let s20_opt = Optimizer::new(&s20, registry, &s20_lut);
    let maw_src: Vec<(String, Option<Design>)> = registry
        .families()
        .iter()
        .map(|f| {
            (f.to_string(),
             s20_opt.optimize(OBJ, &SearchSpace::family(f)).ok().map(|e| e.design))
        })
        .collect();

    let mut rows = Vec::new();
    let mut summaries = Vec::new();
    for device in profiles::profiles() {
        let lut = build_lut(&device, registry)?;
        let opt = Optimizer::new(&device, registry, &lut);
        let mut dev_rows = Vec::new();
        let mut undeployable = Vec::new();

        for family in registry.families() {
            let oodin = opt
                .optimize(OBJ, &SearchSpace::family(family))
                .ok()
                .map(|e| e.latency_ms);
            if oodin.is_none() {
                undeployable.push(family.to_string());
            }
            let paw = paw_design(&opt, registry, family)
                .map(|d| transplant(&device, &d))
                .and_then(|d| eval_on(&opt, &device, registry, &d));
            let maw = maw_src
                .iter()
                .find(|(f, _)| f == family)
                .and_then(|(_, d)| d.clone())
                .map(|d| transplant(&device, &d))
                .and_then(|d| eval_on(&opt, &device, registry, &d));
            dev_rows.push(Fig456Row {
                device: device.name.to_string(),
                family: family.to_string(),
                oodin_ms: oodin,
                paw_ms: paw,
                maw_ms: maw,
            });
        }

        let agg = |pick: fn(&Fig456Row) -> Option<f64>| {
            let sp: Vec<f64> = dev_rows
                .iter()
                .filter_map(|r| match (r.oodin_ms, pick(r)) {
                    (Some(o), Some(b)) => Some(b / o),
                    _ => None,
                })
                .collect();
            if sp.is_empty() {
                None
            } else {
                Some((geomean(&sp), sp.iter().copied().fold(f64::MIN, f64::max)))
            }
        };
        summaries.push(Fig456Summary {
            device: device.name.to_string(),
            vs_paw: agg(|r| r.paw_ms),
            vs_maw: agg(|r| r.maw_ms),
            undeployable,
        });
        rows.extend(dev_rows);
    }
    Ok((rows, summaries))
}

/// Print the Fig 4/5/6 rows (optionally one device only).
pub fn print(registry: &Registry, device_filter: Option<&str>) -> Result<()> {
    let (rows, summaries) = run(registry)?;
    println!("FIG 4/5/6 — OODIn vs PAW-D / MAW-D (p90 latency, ε={EVAL_EPSILON})");
    println!("{:<14} {:<20} {:>10} {:>10} {:>10} {:>7} {:>7}",
             "device", "model", "OODIn ms", "PAW ms", "MAW ms", "xPAW", "xMAW");
    let f = |x: Option<f64>| x.map_or("  undep.".into(), |v| format!("{v:9.4}"));
    for r in rows.iter().filter(|r| device_filter.map_or(true, |d| r.device == d)) {
        let sp = |b: Option<f64>| match (r.oodin_ms, b) {
            (Some(o), Some(b)) => format!("{:6.2}x", b / o),
            _ => "    --".into(),
        };
        println!("{:<14} {:<20} {:>10} {:>10} {:>10} {} {}",
                 r.device, r.family, f(r.oodin_ms), f(r.paw_ms), f(r.maw_ms),
                 sp(r.paw_ms), sp(r.maw_ms));
    }
    println!("{}", crate::experiments::rule(84));
    for s in &summaries {
        let fmt = |x: Option<(f64, f64)>| {
            x.map_or("n/a".into(), |(g, m)| format!("{g:.2}x geo / {m:.2}x max"))
        };
        println!("{:<14} vs PAW-D: {:<26} vs MAW-D: {}",
                 s.device, fmt(s.vs_paw), fmt(s.vs_maw));
        if !s.undeployable.is_empty() {
            println!("{:<14} not deployable: {}", "", s.undeployable.join(", "));
        }
    }
    println!("(paper: Sony ≤2.36x/1.56x; A71 ≤4.3x/3.5x; S20 ≤3.44x, MAW ≡ OODIn on S20)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::test_fixtures::fake_registry;

    #[test]
    fn oodin_never_loses_to_paw_or_maw() {
        let reg = fake_registry();
        let (rows, _) = run(&reg).unwrap();
        for r in &rows {
            if let Some(o) = r.oodin_ms {
                for b in [r.paw_ms, r.maw_ms].into_iter().flatten() {
                    assert!(o <= b + 1e-9, "{r:?}");
                }
            }
        }
    }

    #[test]
    fn maw_equals_oodin_on_flagship() {
        // Fig 6: MAW-D designs coincide with OODIn's on S20.
        let reg = fake_registry();
        let (rows, _) = run(&reg).unwrap();
        for r in rows.iter().filter(|r| r.device == FLAGSHIP) {
            if let (Some(o), Some(m)) = (r.oodin_ms, r.maw_ms) {
                assert!((o - m).abs() < 1e-9, "{r:?}");
            }
        }
    }

    #[test]
    fn transplant_falls_back_npu_to_cpu() {
        let sony = profiles::by_name("sony_c5").unwrap();
        let d = Design {
            variant: "x".into(),
            hw: HwConfig {
                engine: EngineKind::Npu,
                threads: 1,
                governor: crate::dvfs::Governor::EnergyStep, // Sony lacks it
                recognition_rate: 1.0,
                plan: crate::measurements::ExecPlan::Mono,
            },
        };
        let t = transplant(&sony, &d);
        assert_eq!(t.hw.engine, EngineKind::Cpu);
        assert_eq!(t.hw.threads, 1);
        assert_eq!(t.hw.governor, sony.governors[0]);
    }

    #[test]
    fn summaries_cover_all_devices() {
        let reg = fake_registry();
        let (_, summaries) = run(&reg).unwrap();
        assert_eq!(summaries.len(), 3);
        assert!(summaries.iter().any(|s| s.vs_paw.is_some()));
    }
}
