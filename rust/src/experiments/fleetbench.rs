//! Fleet benchmark (`oodin fleet-bench`): population-scale adaptation with
//! transferred LUTs and cohort-shared frontier caches, judged against a
//! full-profile oracle.
//!
//! The driver builds a seeded device fleet ([`crate::fleet`]), transfers
//! one LUT per cohort, then replays a scripted condition storm (calm →
//! GPU surge → NPU thermal wave → recovery) through one
//! [`crate::manager::RuntimeManager`] per device — every manager pointed
//! at its cohort's representative profile, transferred LUT and *shared*
//! frontier cache.  It reports:
//!
//! * **decision regret** — at sampled storm ticks, the transferred-LUT
//!   selection (cohort frontier walk) is re-scored under the device's
//!   *true* measured LUT and compared with the full-profile oracle's
//!   selection (complete search over the true LUT at the exact
//!   conditions).  Regret is the relative true-latency excess;
//! * **cohort cache effectiveness** — frontier builds vs hits across the
//!   population (builds scale with cohorts × visited buckets, not with
//!   devices);
//! * **per-device adaptation decisions** — switches and hold reasons from
//!   the real manager state machine under the storm.
//!
//! After the storm the bench drives the **fleet control plane** end to
//! end ([`run_control_plane`]): a deliberately mispredicted LUT revision
//! is canaried through the staged-rollout state machine and must be
//! auto-rolled-back by the live regret gate (treated cohort LUTs
//! restored bit-identically, zero cohorts left live); a good revision
//! must then widen up the ladder and promote fleet-wide.  Three online
//! residual-feedback rounds fold measured-vs-predicted latencies into
//! per-cohort per-engine corrections through the incremental delta
//! path, cohorts whose accumulated correction crosses the re-anchor
//! threshold are promoted to measured anchors, and a closing regret
//! round must beat the pre-feedback storm mean.
//!
//! The smoke configuration (200 devices, zero measurement noise) is
//! byte-stable and golden-pinned (`tests/golden/fleetbench_smoke.json`),
//! regenerated independently by the Python oracle
//! `python/golden_fleetbench.py` — same N-version convention as
//! `opt-bench` and `serve-bench`.

use anyhow::{bail, ensure, Context, Result};

use crate::designspace::{rank, scoped_fingerprint, ConditionsBucket,
                         DeltaOutcome, DesignSpace, LutDelta};
use crate::device::EngineKind;
use crate::fleet::{CohortReport, FeedbackConfig, FeedbackLoop, Fleet,
                   FleetConfig, IngestOutcome, PopulationConfig,
                   RevisionRegistry, Rollout, RolloutConfig, RolloutOutcome,
                   RolloutStage};
use crate::manager::{adjusted_latency, Conditions, Decision, HoldReason,
                     Reason, RuntimeManager};
use crate::measurements::Lut;
use crate::model::Registry;
use crate::optimizer::{Objective, SearchSpace};
use crate::perf;
use crate::telemetry::trace::FlightRecorder;
use crate::telemetry::{BurnConfig, SloBurnMonitor};
use crate::util::json::{self, Value};
use crate::util::stats::{LatencyStats, Percentile};

use std::sync::Arc;

use super::optbench::{objective_label, SIM_NS_PER_EVAL};
use super::r3;

/// Engine of the fleet-wide online correction replayed after the storm
/// (the probe-fallback shape: one uniform per-engine latency factor).
pub const CORRECTION_ENGINE: EngineKind = EngineKind::Cpu;
/// Uniform latency factor of that correction.
pub const CORRECTION_FACTOR: f64 = 1.25;

/// Engine both control-plane revisions rescale.
pub const ROLLOUT_ENGINE: EngineKind = EngineKind::Cpu;
/// Factor of the deliberately mispredicted revision: CPU rows claimed 4×
/// faster than the cohort believes, flipping CPU-marginal cohorts onto
/// catastrophically regretful selections the canary gate must catch.
pub const ROLLOUT_BAD_FACTOR: f64 = 0.25;
/// Factor of the good revision: (approximately) undoes the post-storm
/// 1.25× CPU correction, so treated cohorts decide no worse than the
/// controls and every gate passes up the ladder.
pub const ROLLOUT_GOOD_FACTOR: f64 = 0.8;
/// SLO latency bound the cohort telemetry reports misses against
/// (a 30 fps frame budget).
pub const ROLLOUT_SLO_MS: f64 = 1000.0 / 30.0;
/// Residual-feedback rounds the control plane drives after promotion.
pub const FEEDBACK_ROUNDS: usize = 3;

/// Per-decision regret (%) SLO the storm's burn-rate monitor watches on
/// the per-cohort `regret_pct` rollups — the storm's acceptance bound.
pub const BURN_SLO_REGRET_PCT: f64 = 5.0;
/// Error budget of that SLO: a quarter of a cohort's decisions may
/// exceed the regret bound before the budget burns at 1×.
pub const BURN_BUDGET: f64 = 0.25;
/// Minimum new samples per cohort per check before the monitor issues a
/// verdict (small cohorts abstain rather than alert on noise).
pub const BURN_MIN_SAMPLES: u64 = 4;

/// Experiment dimensions and depth.
#[derive(Debug, Clone)]
pub struct FleetBenchConfig {
    /// Fleet construction parameters (population, transfer, LUT depth).
    pub fleet: FleetConfig,
    /// Model family every device's app is built around.
    pub family: String,
    /// Per-app objective.
    pub objective: Objective,
    /// Storm length in manager ticks.
    pub ticks: usize,
    /// Milliseconds between ticks (the manager check interval).
    pub tick_ms: f64,
    /// Ticks at which regret is evaluated against the oracle.
    pub regret_ticks: Vec<usize>,
    /// When set, `run` fails if mean regret exceeds this many percent.
    pub enforce_regret_pct: Option<f64>,
}

impl FleetBenchConfig {
    /// The CI-sized, golden-pinned configuration: 200 devices, zero
    /// measurement noise (every latency is the closed-form roofline
    /// prediction), regret enforced at ≤ 5%.
    pub fn smoke() -> Self {
        FleetBenchConfig {
            fleet: FleetConfig::default(),
            family: "mobilenet_v2_100".to_string(),
            objective: Objective::MinLatency {
                stat: Percentile::Avg,
                epsilon: 0.05,
            },
            ticks: 12,
            tick_ms: 250.0,
            regret_ticks: vec![1, 4, 8, 11],
            enforce_regret_pct: Some(5.0),
        }
    }

    /// The full sweep: a 1000-device fleet with realistic measurement
    /// noise (not golden-pinned).
    pub fn full() -> Self {
        let mut cfg = FleetBenchConfig::smoke();
        cfg.fleet.population = PopulationConfig {
            size: 1000,
            ..PopulationConfig::default()
        };
        cfg.fleet.lut_runs = 20;
        cfg.fleet.lut_warmup = 2;
        cfg.fleet.noise_sigma = 0.02;
        cfg.fleet.transfer.noise_sigma = 0.02;
        cfg.enforce_regret_pct = None;
        cfg
    }
}

/// Storm phase label of a tick.
pub fn storm_phase(tick: usize) -> &'static str {
    match tick {
        0..=2 => "calm",
        3..=6 => "gpu_surge",
        7..=9 => "npu_throttle",
        _ => "recovery",
    }
}

/// Scripted per-device conditions at a storm tick.  Loads sit on
/// conditions-bucket centres (exact powers of two) so the smoke report
/// stays closed-form.
pub fn storm_conditions(tick: usize, device_idx: usize, has_npu: bool)
                        -> Conditions {
    let mut c = Conditions::idle();
    match storm_phase(tick) {
        "gpu_surge" => {
            if device_idx % 2 == 0 {
                c.loads.insert(EngineKind::Gpu, 1.0);
            }
        }
        "npu_throttle" => {
            if has_npu {
                c.thermal.insert(EngineKind::Npu, 0.5);
            } else {
                c.loads.insert(EngineKind::Cpu, 1.0);
            }
        }
        _ => {}
    }
    c
}

/// Hold-reason histogram over every manager tick.
#[derive(Debug, Clone, Copy, Default)]
pub struct HoldCounts {
    /// Check interval not elapsed.
    pub not_due: u64,
    /// Post-switch quiet period.
    pub cooldown: u64,
    /// Stable conditions, nothing to react to.
    pub no_trigger: u64,
    /// Trigger fired but no feasible alternative.
    pub no_alternative: u64,
    /// Re-search picked the running design.
    pub current_still_best: u64,
    /// Alternative won by less than the hysteresis margin.
    pub below_hysteresis: u64,
}

/// One cohort's summary row in the report.
#[derive(Debug, Clone)]
pub struct CohortRow {
    /// Canonical cohort id.
    pub id: String,
    /// Member device count.
    pub members: usize,
    /// True when any engine ran the probe fallback.
    pub probed: bool,
    /// Lowest per-engine transfer confidence (worst member).
    pub min_confidence: f64,
    /// Frontier builds charged to this cohort's shared cache.
    pub builds: u64,
    /// Frontier hits served by this cohort's shared cache.
    pub hits: u64,
}

/// Everything the staged-rollout + residual-feedback scenario measured
/// ([`run_control_plane`]).
#[derive(Debug, Clone, Default)]
pub struct ControlPlaneReport {
    /// Telemetry samples in the pre-canary baseline round.
    pub baseline_samples: u64,
    /// Id of the deliberately mispredicted revision.
    pub bad_revision: u64,
    /// Final stage of the bad rollout (must be `rolled_back`).
    pub bad_stage: String,
    /// The gate that rolled it back.
    pub bad_reason: String,
    /// Mean canary-cohort regret (%) observed while the bad revision was
    /// live.
    pub bad_canary_regret_pct: f64,
    /// Mean concurrent control-cohort regret (%) in the same round.
    pub bad_control_regret_pct: f64,
    /// Cohorts still carrying the bad revision after rollback (must be
    /// 0).
    pub bad_live_cohorts: usize,
    /// Treated-cohort LUT scope fingerprints restored bit-identically by
    /// the rollback.
    pub rollback_fingerprints_match: bool,
    /// Id of the good revision.
    pub good_revision: u64,
    /// Final stage of the good rollout (must be `promoted`).
    pub good_stage: String,
    /// Evaluation rounds the good rollout took to promote.
    pub good_rounds: usize,
    /// Cohorts carrying the good revision after promotion (must be all).
    pub good_live_cohorts: usize,
    /// Duplicate telemetry reports rejected by ingestion.
    pub duplicates_rejected: u64,
    /// Frontier-cache lookups made by the control plane's own telemetry
    /// sweeps — the scenario's analogue of `cache_bench_lookups`, fully
    /// accounted against the cache counters.
    pub lookups: u64,
    /// Residual-feedback rounds driven.
    pub feedback_rounds: usize,
    /// Residual observations folded across those rounds.
    pub feedback_samples: u64,
    /// (cohort, engine) corrections applied.
    pub feedback_corrections: u64,
    /// Mean |ln(measured/predicted)| per round (must not grow round over
    /// round).
    pub residual_mean_abs_ln: Vec<f64>,
    /// Frontiers the feedback corrections carried in place.
    pub feedback_delta_updated: u64,
    /// Frontier points those corrections' delta paths touched.
    pub feedback_delta_points_touched: u64,
    /// Candidates full rebuilds of the same frontiers would have scored.
    pub feedback_delta_rebuild_points: u64,
    /// Cohorts promoted to measured anchors by the accumulated-correction
    /// threshold.
    pub re_anchored_cohorts: usize,
    /// Frontier rebuilds the closing regret round paid for re-anchored
    /// cohorts (their caches invalidate lazily on first access).
    pub post_feedback_builds: u64,
    /// Closing-round mean regret (%).
    pub post_regret_mean_pct: f64,
    /// Closing-round worst regret (%).
    pub post_regret_max_pct: f64,
    /// Closing-round deploy faults.
    pub post_deploy_faults: u64,
    /// Closing-round mean regret ≤ the pre-feedback storm mean
    /// (compared un-rounded).
    pub regret_improved: bool,
}

/// The aggregated fleet-bench report.
#[derive(Debug)]
pub struct FleetBenchReport {
    /// The configuration the report was produced under.
    pub cfg: FleetBenchConfig,
    /// Devices per archetype, in sampling order.
    pub archetype_counts: Vec<(&'static str, usize)>,
    /// Units whose NPU was dropped by the availability axis.
    pub npu_dropped: usize,
    /// Per-cohort summary rows.
    pub cohorts: Vec<CohortRow>,
    /// Cohorts that ran the probe fallback.
    pub probed_cohorts: usize,
    /// Probe configurations measured across the fleet.
    pub probe_measurements: usize,
    /// Mean |predicted − true|/true over the family's LUT entries (%).
    pub pred_err_mean_pct: f64,
    /// Worst such error (%).
    pub pred_err_max_pct: f64,
    /// Manager decisions taken (ticks × devices).
    pub decisions: u64,
    /// Reconfigurations issued.
    pub switches: u64,
    /// Switches triggered by load change.
    pub switch_load: u64,
    /// Switches triggered by confirmed degradation.
    pub switch_degradation: u64,
    /// Hold-reason histogram.
    pub holds: HoldCounts,
    /// Devices that switched at least once.
    pub devices_switched: usize,
    /// Largest per-device switch count.
    pub max_switches_per_device: u64,
    /// Regret samples evaluated (regret ticks × devices).
    pub regret_events: usize,
    /// Mean regret (%).
    pub regret_mean_pct: f64,
    /// Worst regret (%).
    pub regret_max_pct: f64,
    /// Fraction of events with (near-)zero regret.
    pub regret_zero_share: f64,
    /// Transferred selections inadmissible under the device's true
    /// memory/deployability filters.
    pub deploy_faults: u64,
    /// Frontier builds across every cohort cache.
    pub cache_builds: u64,
    /// Frontier hits across every cohort cache.
    pub cache_hits: u64,
    /// Cache lookups made by the bench's own regret instrumentation (one
    /// per regret event) — included in `cache_builds`/`cache_hits`, broken
    /// out so the adaptation-path rate can be read separately.
    pub cache_bench_lookups: u64,
    /// LRU evictions across every cohort cache.
    pub cache_evictions: u64,
    /// Candidates enumerated by frontier builds across every cohort cache
    /// (the amortised decision cost the rate below is computed from).
    pub candidates_enumerated: u64,
    /// Cohort-cache frontiers carried in place by the post-storm
    /// per-engine correction.
    pub delta_updated: u64,
    /// Frontier points the correction's delta path touched.
    pub delta_points_touched: u64,
    /// Candidates full rebuilds of the same frontiers would have scored.
    pub delta_rebuild_points: u64,
    /// Frontiers updated when every device's manager re-applied the same
    /// correction to its cohort-shared cache (must be 0: idempotent).
    pub idempotent_reapply_updates: u64,
    /// Frontier builds during the post-correction idle round (must be 0:
    /// the correction keeps every visited bucket warm).
    pub post_correction_builds: u64,
    /// Accounted resident bytes across every cohort cache.
    pub resident_bytes: u64,
    /// Byte budget each cohort cache runs under
    /// ([`FleetConfig::frontier_mem_budget_bytes`] split evenly).
    pub mem_budget_per_cohort: u64,
    /// Fleet-wide regret distribution (%) from the per-cohort telemetry
    /// rollup — bounded log-scaled histograms merged across every cohort
    /// sink; `None` when no regret ticks ran.
    pub rollup_regret: Option<LatencyStats>,
    /// Bytes resident across every cohort telemetry sink (constant in
    /// sample count).
    pub telemetry_resident_bytes: usize,
    /// The staged-rollout + residual-feedback scenario outcome.
    pub control_plane: ControlPlaneReport,
}

/// The full-profile oracle's selection: complete search over the device's
/// true LUT at the *exact* observed conditions.
fn oracle_pick(fleet: &Fleet, device_idx: usize, true_lut: &Lut,
               objective: Objective, space: &SearchSpace,
               conds: &Conditions)
               -> Result<crate::designspace::Candidate> {
    let ds = DesignSpace::new(&fleet.devices[device_idx].profile,
                              &fleet.registry, true_lut);
    let ranked = rank(ds.enumerate(objective, space, conds), objective);
    ranked.into_iter().next().with_context(|| {
        format!("{}: oracle found no feasible design",
                fleet.devices[device_idx].id)
    })
}

/// One control-plane telemetry round: every device re-selected at the
/// storm's regret-tick condition snapshots, scored against the
/// (precomputed) oracle, aggregated into per-cohort [`CohortReport`]s.
struct SweepOutcome {
    /// One report per cohort, canonical order, tagged with the cohort's
    /// live revision.
    reports: Vec<CohortReport>,
    /// Per-event regret values (deploy-fault-clamped, fractions).
    regrets: Vec<f64>,
    /// Frontier-cache lookups the sweep made.
    lookups: u64,
}

fn control_sweep(fleet: &Fleet, reg: &RevisionRegistry, oracle_luts: &[Lut],
                 oracle_adj: &[Vec<f64>], objective: Objective,
                 space: &SearchSpace, regret_ticks: &[usize], seq: u64)
                 -> Result<SweepOutcome> {
    let mut reports: Vec<CohortReport> = (0..fleet.cohorts.len())
        .map(|ci| CohortReport {
            cohort: ci,
            revision: reg.live(ci),
            seq,
            samples: 0,
            regret_pct_sum: 0.0,
            slo_misses: 0,
            deploy_faults: 0,
        })
        .collect();
    let mut regrets = Vec::with_capacity(regret_ticks.len() * fleet.len());
    let mut lookups = 0u64;
    for (ti, &tick) in regret_ticks.iter().enumerate() {
        for idx in 0..fleet.len() {
            let conds = storm_conditions(tick, idx,
                                         fleet.devices[idx].has_npu());
            let sel = fleet.select(idx, objective, space, &conds)?;
            lookups += 1;
            let true_lut = &oracle_luts[idx];
            let sel_adj = adjusted_latency(true_lut, &sel, objective.stat(),
                                           &conds)
                .with_context(|| format!("{}: control-plane pick absent \
                                          from the true LUT",
                                         fleet.devices[idx].id))?;
            let entry = true_lut.get(&sel.lut_key()).unwrap();
            let v = fleet.registry.get(&sel.variant).unwrap();
            let admissible =
                perf::fits_memory(&fleet.devices[idx].profile, v)
                    && entry.latency.avg
                        <= fleet.devices[idx].profile
                            .max_deployable_latency_ms;
            let r = sel_adj / oracle_adj[ti][idx] - 1.0;
            let rep = &mut reports[fleet.device_cohort[idx]];
            let rv = if admissible {
                r
            } else {
                rep.deploy_faults += 1;
                r.max(0.0)
            };
            regrets.push(rv);
            rep.samples += 1;
            rep.regret_pct_sum += 100.0 * rv;
            if sel_adj > ROLLOUT_SLO_MS {
                rep.slo_misses += 1;
            }
        }
    }
    Ok(SweepOutcome { reports, regrets, lookups })
}

/// Drive the fleet control plane over the post-storm fleet: canary and
/// auto-roll-back the mispredicted revision, canary → widen → promote
/// the good one, run [`FEEDBACK_ROUNDS`] residual-feedback rounds,
/// re-anchor drifted cohorts, and verify the closing regret round beats
/// `pre_regret_mean` (the storm's un-rounded mean regret fraction).
///
/// Hard scenario invariants (rollback restores fingerprints, the bad
/// revision dies with zero live cohorts, promotion covers the fleet,
/// duplicates never double-count, every lookup is accounted) are always
/// enforced; the statistical ones (residual convergence, regret
/// improvement, selective re-anchoring) only under
/// [`FleetBenchConfig::enforce_regret_pct`], like the storm's own
/// acceptance gates.
pub fn run_control_plane(fleet: &mut Fleet, managers: &mut [RuntimeManager],
                         oracle_luts: &[Lut], cfg: &FleetBenchConfig,
                         objective: Objective, space: &SearchSpace,
                         recorder: Option<&Arc<FlightRecorder>>,
                         pre_regret_mean: f64)
                         -> Result<ControlPlaneReport> {
    let enforce = cfg.enforce_regret_pct.is_some();
    let step_us = (cfg.tick_ms * 1000.0) as u64;
    let base_us = cfg.ticks as u64 * step_us;
    let mut k = 0u64;
    let mut advance_clock = |k: &mut u64| {
        *k += 1;
        if let Some(rec) = recorder {
            rec.set_now_us(base_us + *k * step_us);
        }
    };

    // The oracle's adjusted latency per (regret tick, device): true LUTs
    // never change, so every sweep reuses one full-search pass.
    let mut oracle_adj =
        vec![vec![0.0f64; fleet.len()]; cfg.regret_ticks.len()];
    for (ti, &tick) in cfg.regret_ticks.iter().enumerate() {
        for idx in 0..fleet.len() {
            let conds = storm_conditions(tick, idx,
                                         fleet.devices[idx].has_npu());
            let oracle = oracle_pick(fleet, idx, &oracle_luts[idx],
                                     objective, space, &conds)?;
            oracle_adj[ti][idx] =
                adjusted_latency(&oracle_luts[idx], &oracle.design,
                                 objective.stat(), &conds)
                    .context("oracle pick absent from the true LUT")?;
        }
    }

    let pre_cache = fleet.cache_stats();
    let mut lookups = 0u64;
    let rollout_cfg = RolloutConfig::default();
    let mut reg = RevisionRegistry::new(fleet.cohorts.len());

    // Pre-canary baseline round: anchors the self-controlled SLO/fault
    // gates of both rollouts.
    advance_clock(&mut k);
    let baseline = control_sweep(fleet, &reg, oracle_luts, &oracle_adj,
                                 objective, space, &cfg.regret_ticks, 0)?;
    lookups += baseline.lookups;

    // -- the mispredicted revision: canary, gate breach, auto-rollback --
    let bad_rev = reg.register(ROLLOUT_ENGINE, ROLLOUT_BAD_FACTOR);
    let mut bad = Rollout::new(bad_rev, rollout_cfg.clone());
    for rep in &baseline.reports {
        ensure!(bad.ingest(*rep, &reg) == IngestOutcome::Accepted,
                "baseline report rejected");
    }
    let canary_n = rollout_cfg
        .ladder
        .first()
        .copied()
        .unwrap_or(fleet.cohorts.len())
        .min(fleet.cohorts.len());
    let fingerprint = |fleet: &Fleet, ci: usize| {
        scoped_fingerprint(&fleet.cohorts[ci].lut, &fleet.registry, space)
    };
    let pre_fps: Vec<u64> =
        (0..canary_n).map(|ci| fingerprint(fleet, ci)).collect();
    advance_clock(&mut k);
    bad.begin_canary(fleet, &mut reg)?;
    advance_clock(&mut k);
    let bad_sweep = control_sweep(fleet, &reg, oracle_luts, &oracle_adj,
                                  objective, space, &cfg.regret_ticks, 1)?;
    lookups += bad_sweep.lookups;
    for rep in &bad_sweep.reports {
        ensure!(bad.ingest(*rep, &reg) == IngestOutcome::Accepted,
                "canary report rejected");
    }
    let bad_reason = match bad.evaluate(fleet, &mut reg) {
        RolloutOutcome::RolledBack { reason } => reason,
        other => bail!("mispredicted revision survived its canary: \
                        {other:?}"),
    };
    ensure!(reg.live_count(bad_rev.id) == 0,
            "bad revision still live on {} cohorts after rollback",
            reg.live_count(bad_rev.id));
    let post_fps: Vec<u64> =
        (0..canary_n).map(|ci| fingerprint(fleet, ci)).collect();
    ensure!(pre_fps == post_fps,
            "rollback failed to restore treated cohort LUTs bit-identically");
    let treated = bad.treated().to_vec();
    let (mut tsum, mut tn, mut csum, mut cn) = (0.0, 0u64, 0.0, 0u64);
    for rep in &bad_sweep.reports {
        if treated.contains(&rep.cohort) {
            tsum += rep.regret_pct_sum;
            tn += rep.samples;
        } else {
            csum += rep.regret_pct_sum;
            cn += rep.samples;
        }
    }
    let bad_canary_regret = tsum / tn.max(1) as f64;
    let bad_control_regret = csum / cn.max(1) as f64;

    // -- the good revision: canary, widen up the ladder, promote --
    let good_rev = reg.register(ROLLOUT_ENGINE, ROLLOUT_GOOD_FACTOR);
    let mut good = Rollout::new(good_rev, rollout_cfg.clone());
    for rep in &baseline.reports {
        ensure!(good.ingest(*rep, &reg) == IngestOutcome::Accepted,
                "baseline report rejected");
    }
    advance_clock(&mut k);
    good.begin_canary(fleet, &mut reg)?;
    let mut good_rounds = 0usize;
    let mut seq = 2u64;
    loop {
        advance_clock(&mut k);
        let sweep = control_sweep(fleet, &reg, oracle_luts, &oracle_adj,
                                  objective, space, &cfg.regret_ticks,
                                  seq)?;
        lookups += sweep.lookups;
        for rep in &sweep.reports {
            ensure!(good.ingest(*rep, &reg) == IngestOutcome::Accepted,
                    "widening report rejected");
        }
        if good_rounds == 0 {
            // A replayed (cohort, seq) report must be discarded, never
            // double-counted against the gates.
            ensure!(good.ingest(sweep.reports[0], &reg)
                        == IngestOutcome::Duplicate,
                    "duplicate report was not rejected");
        }
        good_rounds += 1;
        seq += 1;
        match good.evaluate(fleet, &mut reg) {
            RolloutOutcome::Promoted => break,
            RolloutOutcome::Advanced { .. } => {}
            other => bail!("good revision failed to advance: {other:?}"),
        }
        ensure!(good_rounds <= fleet.cohorts.len(),
                "rollout failed to terminate");
    }
    ensure!(good.stage() == RolloutStage::Promoted
                && reg.live_count(good_rev.id) == fleet.cohorts.len(),
            "promotion must cover the fleet: {}/{} cohorts live",
            reg.live_count(good_rev.id), fleet.cohorts.len());

    // -- residual feedback: observe, correct through the delta path --
    let fb_cfg = FeedbackConfig::default();
    let mut fb = FeedbackLoop::new(fb_cfg.clone());
    let mut residual_rounds: Vec<f64> = Vec::new();
    let mut fb_samples = 0u64;
    let mut fb_corrections = 0u64;
    let mut fb_delta = DeltaOutcome::default();
    for _ in 0..FEEDBACK_ROUNDS {
        advance_clock(&mut k);
        for &tick in &cfg.regret_ticks {
            for idx in 0..fleet.len() {
                let conds = storm_conditions(tick, idx,
                                             fleet.devices[idx].has_npu());
                let sel = fleet.select(idx, objective, space, &conds)?;
                lookups += 1;
                let ci = fleet.device_cohort[idx];
                let key = sel.lut_key();
                let measured = oracle_luts[idx]
                    .get(&key)
                    .with_context(|| format!("{}: feedback pick absent \
                                              from the true LUT",
                                             fleet.devices[idx].id))?
                    .latency
                    .avg;
                let predicted = fleet.cohorts[ci]
                    .lut
                    .get(&key)
                    .with_context(|| format!("{}: feedback pick absent \
                                              from the cohort LUT",
                                             fleet.cohorts[ci].id))?
                    .latency
                    .avg;
                let measured_adj =
                    adjusted_latency(&oracle_luts[idx], &sel,
                                     objective.stat(), &conds)
                        .context("feedback pick absent from the true LUT")?;
                // What the device actually observed, into the manager's
                // degradation window — the production ingest point.
                managers[idx].record_latency(measured_adj);
                fb.observe(ci, sel.hw.engine, measured, predicted);
            }
        }
        let round = fb.apply_round(fleet);
        fb_samples += round.samples;
        fb_corrections += round.corrections;
        residual_rounds.push(round.mean_abs_ln);
        fb_delta.absorb(round.delta);
    }
    if enforce {
        for w in residual_rounds.windows(2) {
            ensure!(w[1] <= w[0] + 1e-9,
                    "residual feedback failed to converge: \
                     {residual_rounds:?}");
        }
    }

    // -- re-anchor drifted cohorts, then the closing regret round --
    advance_clock(&mut k);
    let anchors = fb.re_anchor(fleet)?;
    if enforce {
        ensure!(!anchors.is_empty(),
                "no cohort crossed the re-anchor threshold");
        ensure!(anchors.len() < fleet.cohorts.len(),
                "re-anchoring must stay selective: {}/{} cohorts",
                anchors.len(), fleet.cohorts.len());
    }
    let builds_before_post = fleet.cache_stats().builds;
    advance_clock(&mut k);
    let post = control_sweep(fleet, &reg, oracle_luts, &oracle_adj,
                             objective, space, &cfg.regret_ticks, seq)?;
    lookups += post.lookups;
    let post_builds = fleet.cache_stats().builds - builds_before_post;
    let post_mean = post.regrets.iter().sum::<f64>()
        / post.regrets.len().max(1) as f64;
    let post_max = post.regrets.iter().fold(0.0f64, |a, &b| a.max(b));
    let post_faults: u64 =
        post.reports.iter().map(|r| r.deploy_faults).sum();
    let improved = post_mean <= pre_regret_mean;
    if enforce {
        ensure!(improved,
                "post-feedback mean regret {:.3}% exceeds the pre-feedback \
                 {:.3}%",
                100.0 * post_mean, 100.0 * pre_regret_mean);
    }

    // Every control-plane lookup accounted: the scenario cannot have
    // contaminated the storm's regret metric (computed before it ran),
    // and its cache traffic is fully explained by its own sweeps.
    let after = fleet.cache_stats();
    ensure!(after.builds + after.hits - pre_cache.builds - pre_cache.hits
                == lookups,
            "control-plane cache traffic unaccounted: {} lookups vs {} \
             counted",
            after.builds + after.hits - pre_cache.builds - pre_cache.hits,
            lookups);
    for c in &fleet.cohorts {
        ensure!(c.mem_budget() == 0 || c.resident_bytes() <= c.mem_budget(),
                "{}: resident {} B over the {} B cohort budget after the \
                 control plane",
                c.id, c.resident_bytes(), c.mem_budget());
    }

    Ok(ControlPlaneReport {
        baseline_samples: baseline.reports.iter().map(|r| r.samples).sum(),
        bad_revision: bad_rev.id,
        bad_stage: bad.stage().name().to_string(),
        bad_reason,
        bad_canary_regret_pct: r3(bad_canary_regret),
        bad_control_regret_pct: r3(bad_control_regret),
        bad_live_cohorts: reg.live_count(bad_rev.id),
        rollback_fingerprints_match: pre_fps == post_fps,
        good_revision: good_rev.id,
        good_stage: good.stage().name().to_string(),
        good_rounds,
        good_live_cohorts: reg.live_count(good_rev.id),
        duplicates_rejected: good.duplicates(),
        lookups,
        feedback_rounds: FEEDBACK_ROUNDS,
        feedback_samples: fb_samples,
        feedback_corrections: fb_corrections,
        residual_mean_abs_ln: residual_rounds.iter().map(|&v| r3(v))
            .collect(),
        feedback_delta_updated: fb_delta.updated,
        feedback_delta_points_touched: fb_delta.points_touched,
        feedback_delta_rebuild_points: fb_delta.rebuild_points,
        re_anchored_cohorts: anchors.len(),
        post_feedback_builds: post_builds,
        post_regret_mean_pct: r3(100.0 * post_mean),
        post_regret_max_pct: r3(100.0 * post_max),
        post_deploy_faults: post_faults,
        regret_improved: improved,
    })
}

/// Run the fleet benchmark.
pub fn run(registry: &Registry, cfg: &FleetBenchConfig)
           -> Result<FleetBenchReport> {
    run_traced(registry, cfg, None)
}

/// [`run`] with an optional flight recorder: cohort-transfer provenance,
/// every frontier-cache transition, every per-device decide outcome, the
/// post-storm correction and the whole control-plane scenario (rollout
/// stage transitions, residual corrections, anchor promotions) land in
/// the trace, stamped with the storm's deterministic virtual clock
/// (µs = tick × tick_ms × 1000; the control plane continues the clock
/// past the storm at the same cadence).  Recording never changes a
/// decision, a cache counter, or the report.
pub fn run_traced(registry: &Registry, cfg: &FleetBenchConfig,
                  recorder: Option<&Arc<FlightRecorder>>)
                  -> Result<FleetBenchReport> {
    let mut fleet = Fleet::build(std::sync::Arc::new(registry.clone()),
                                 cfg.fleet.clone())?;
    if let Some(rec) = recorder {
        rec.set_now_us(0);
        fleet.attach_recorder(rec);
    }
    let space = SearchSpace::family(&cfg.family);
    let objective = cfg.objective;

    // Population summary.
    let mut archetype_counts: Vec<(&'static str, usize)> =
        crate::fleet::population::ARCHETYPES
            .iter()
            .map(|&a| (a, 0usize))
            .collect();
    let mut npu_dropped = 0usize;
    for d in &fleet.devices {
        if let Some(c) = archetype_counts.iter_mut().find(|c| c.0 == d.archetype)
        {
            c.1 += 1;
        }
        if d.dropped_npu {
            npu_dropped += 1;
        }
    }

    // Full-profile oracle LUTs (what per-device profiling would have
    // produced) and the transfer prediction error against them.
    let mut oracle_luts = Vec::with_capacity(fleet.len());
    let mut err_sum = 0.0;
    let mut err_max = 0.0f64;
    let mut err_n = 0usize;
    for idx in 0..fleet.len() {
        let true_lut = fleet.oracle_lut(idx)?;
        let cohort = fleet.cohort_of(idx);
        for (k, pred) in &cohort.lut.entries {
            let fam = &registry
                .get(&k.variant)
                .with_context(|| format!("variant {}", k.variant))?
                .family;
            if fam != &cfg.family {
                continue;
            }
            let truth = true_lut
                .get(k)
                .with_context(|| format!("{}: oracle missing {}",
                                         fleet.devices[idx].id, k.id()))?;
            let err = (pred.latency.avg / truth.latency.avg - 1.0).abs();
            err_sum += err;
            err_max = err_max.max(err);
            err_n += 1;
        }
        oracle_luts.push(true_lut);
    }

    // One RuntimeManager per device over the cohort-shared state.
    let mut managers: Vec<RuntimeManager> = Vec::with_capacity(fleet.len());
    for idx in 0..fleet.len() {
        let mut m = fleet.manager_for(idx, objective, &space)?;
        if let Some(rec) = recorder {
            m = m.with_recorder(Arc::clone(rec), &fleet.devices[idx].id);
        }
        managers.push(m);
    }

    // The storm.  The burn-rate monitor watches every cohort's
    // `regret_pct` rollup at each regret tick: its fast window is one
    // regret round, its slow window the storm so far.  Alerts land in
    // the trace as `slo_burn` events; they never touch the report.
    let mut burn_monitor = SloBurnMonitor::new(BurnConfig {
        threshold: BURN_SLO_REGRET_PCT,
        budget: BURN_BUDGET,
        min_samples: BURN_MIN_SAMPLES,
    });
    let mut holds = HoldCounts::default();
    let mut switches = 0u64;
    let mut switch_load = 0u64;
    let mut switch_degradation = 0u64;
    let mut per_device_switches = vec![0u64; fleet.len()];
    let mut regrets: Vec<f64> = Vec::new();
    let mut deploy_faults = 0u64;
    for tick in 0..cfg.ticks {
        let now_ms = tick as f64 * cfg.tick_ms;
        if let Some(rec) = recorder {
            rec.set_now_us((now_ms * 1000.0) as u64);
        }
        let regret_tick = cfg.regret_ticks.contains(&tick);
        for idx in 0..fleet.len() {
            let has_npu = fleet.devices[idx].has_npu();
            let conds = storm_conditions(tick, idx, has_npu);
            let sink = Arc::clone(&fleet.cohort_of(idx).telemetry);
            sink.incr("decisions");
            match managers[idx].decide(now_ms, &conds) {
                Decision::Switch(sw) => {
                    sink.incr("switches");
                    switches += 1;
                    per_device_switches[idx] += 1;
                    match sw.reason {
                        Reason::LoadChange => switch_load += 1,
                        Reason::Degradation => switch_degradation += 1,
                    }
                }
                Decision::Hold(h) => match h {
                    HoldReason::NotDue => holds.not_due += 1,
                    HoldReason::Cooldown { .. } => holds.cooldown += 1,
                    HoldReason::NoTrigger => holds.no_trigger += 1,
                    HoldReason::NoAlternative => holds.no_alternative += 1,
                    HoldReason::CurrentStillBest => {
                        holds.current_still_best += 1
                    }
                    HoldReason::BelowHysteresis { .. } => {
                        holds.below_hysteresis += 1
                    }
                },
            }
            if regret_tick {
                let sel = fleet.select(idx, objective, &space, &conds)?;
                // In-binary exactness re-check: the cohort frontier walk
                // must equal a full search over the cohort LUT at the
                // bucket's representative conditions.
                let bucket = ConditionsBucket::of(&conds);
                let cohort = fleet.cohort_of(idx);
                let ds = DesignSpace::new(&cohort.rep, &fleet.registry,
                                          &cohort.lut);
                let full = rank(
                    ds.enumerate(objective, &space, &bucket.representative()),
                    objective,
                );
                ensure!(
                    full.first().map(|c| &c.design) == Some(&sel),
                    "{}@t{}: frontier walk diverged from full search",
                    fleet.devices[idx].id, tick
                );

                let true_lut = &oracle_luts[idx];
                let oracle = oracle_pick(&fleet, idx, true_lut, objective,
                                         &space, &conds)?;
                let sel_adj = adjusted_latency(true_lut, &sel,
                                               objective.stat(), &conds)
                    .with_context(|| format!("{}: transferred pick absent \
                                              from the true LUT",
                                             fleet.devices[idx].id))?;
                let oracle_adj = adjusted_latency(true_lut, &oracle.design,
                                                  objective.stat(), &conds)
                    .context("oracle pick absent from the true LUT")?;
                let entry = true_lut.get(&sel.lut_key()).unwrap();
                let v = registry.get(&sel.variant).unwrap();
                let admissible =
                    perf::fits_memory(&fleet.devices[idx].profile, v)
                        && entry.latency.avg
                            <= fleet.devices[idx].profile
                                .max_deployable_latency_ms;
                let r = sel_adj / oracle_adj - 1.0;
                // An inadmissible pick can undercut the (feasible-only)
                // oracle; clamping its regret at 0 keeps the headline mean
                // from being flattered by deployability faults — the fault
                // counter, not a negative regret, is their signal.
                let rv = if admissible {
                    r
                } else {
                    deploy_faults += 1;
                    r.max(0.0)
                };
                regrets.push(rv);
                sink.record("regret_pct", 100.0 * rv);
            }
        }
        if regret_tick {
            fleet.check_burn(&mut burn_monitor, "regret_pct",
                             (now_ms * 1000.0) as u64);
        }
    }

    let regret_events = regrets.len();
    let regret_sum: f64 = regrets.iter().sum();
    let regret_mean = regret_sum / regret_events.max(1) as f64;
    let regret_max = regrets.iter().fold(0.0f64, |a, &b| a.max(b));
    let zero = regrets.iter().filter(|&&r| r <= 1e-12).count();

    let stats = fleet.cache_stats();
    // The acceptance-criteria ensures are tied to the regret enforcement:
    // ad-hoc invocations (e.g. `--smoke --devices 20`, where the cohort
    // count can approach the device count) are reported, not aborted.
    if let Some(limit) = cfg.enforce_regret_pct {
        ensure!(
            stats.builds < fleet.len() as u64,
            "cohort sharing must amortise: {} frontier builds for {} devices",
            stats.builds, fleet.len()
        );
        ensure!(
            100.0 * regret_mean <= limit,
            "mean transferred-LUT regret {:.3}% exceeds the {limit}% bound",
            100.0 * regret_mean
        );
    }

    let cohorts: Vec<CohortRow> = fleet
        .cohorts
        .iter()
        .map(|c| {
            let s = c.cache_stats();
            CohortRow {
                id: c.id.clone(),
                members: c.members.len(),
                probed: c.probed(),
                min_confidence: c.min_confidence(),
                builds: s.builds,
                hits: s.hits,
            }
        })
        .collect();
    let probed_cohorts = fleet.cohorts.iter().filter(|c| c.probed()).count();
    let probe_measurements: usize = fleet
        .cohorts
        .iter()
        .flat_map(|c| c.transfer.values())
        .map(|t| t.probes)
        .sum();

    // -- post-storm online correction through the incremental delta path --
    // The probe-fallback shape at fleet scale: every cohort's CPU rows 25%
    // slower.  Cohort caches must be carried in place (no cold starts),
    // per-manager re-application must be idempotent on the shared caches,
    // and a follow-up idle round must be served entirely from warm
    // frontiers.
    if let Some(rec) = recorder {
        rec.set_now_us((cfg.ticks as f64 * cfg.tick_ms * 1000.0) as u64);
    }
    let delta = LutDelta::engine_scale(CORRECTION_ENGINE, CORRECTION_FACTOR);
    let correction =
        fleet.apply_engine_correction(CORRECTION_ENGINE, CORRECTION_FACTOR);
    ensure!(correction.dropped == 0,
            "correction dropped {} warm cohort frontiers", correction.dropped);
    ensure!(correction.updated == 0
                || correction.points_touched < correction.rebuild_points,
            "delta path touched {} points but full rebuilds would score \
             only {}",
            correction.points_touched, correction.rebuild_points);
    if cfg.enforce_regret_pct.is_some() {
        ensure!(correction.updated > 0,
                "the smoke storm must leave warm cohort frontiers for the \
                 correction to carry");
    }
    let mut idempotent_reapply_updates = 0u64;
    for idx in 0..fleet.len() {
        let new_lut = std::sync::Arc::clone(&fleet.cohort_of(idx).lut);
        let re = managers[idx].apply_lut_delta(new_lut, &delta);
        ensure!(re.dropped == 0,
                "{}: manager re-apply dropped {} frontiers",
                fleet.devices[idx].id, re.dropped);
        idempotent_reapply_updates += re.updated;
    }
    ensure!(idempotent_reapply_updates == 0,
            "per-manager re-apply must be idempotent on shared caches, \
             updated {idempotent_reapply_updates} frontiers");
    let builds_before = fleet.cache_stats().builds;
    let idle = Conditions::idle();
    for idx in 0..fleet.len() {
        let sel = fleet.select(idx, objective, &space, &idle)?;
        let cohort = fleet.cohort_of(idx);
        let ds = DesignSpace::new(&cohort.rep, &fleet.registry, &cohort.lut);
        let full = rank(ds.enumerate(objective, &space, &idle), objective);
        ensure!(full.first().map(|c| &c.design) == Some(&sel),
                "{}: post-correction frontier walk diverged from full \
                 search",
                fleet.devices[idx].id);
    }
    let post_correction_builds = fleet.cache_stats().builds - builds_before;
    ensure!(post_correction_builds == 0,
            "correction left {post_correction_builds} cohort buckets cold");
    for c in &fleet.cohorts {
        ensure!(c.mem_budget() == 0 || c.resident_bytes() <= c.mem_budget(),
                "{}: resident {} B over the {} B cohort budget",
                c.id, c.resident_bytes(), c.mem_budget());
    }
    let resident_bytes = fleet.resident_bytes();
    let mem_budget_per_cohort =
        fleet.cohorts.first().map(|c| c.mem_budget()).unwrap_or(0);
    let rollup = fleet.rollup();
    let rollup_regret = rollup.stats("regret_pct");
    let telemetry_resident_bytes: usize =
        fleet.cohorts.iter().map(|c| c.telemetry.resident_bytes()).sum();

    // -- the fleet control plane: staged rollouts + residual feedback --
    let control_plane =
        run_control_plane(&mut fleet, &mut managers, &oracle_luts, cfg,
                          objective, &space, recorder, regret_mean)?;

    Ok(FleetBenchReport {
        cfg: cfg.clone(),
        archetype_counts,
        npu_dropped,
        cohorts,
        probed_cohorts,
        probe_measurements,
        pred_err_mean_pct: r3(100.0 * err_sum / err_n.max(1) as f64),
        pred_err_max_pct: r3(100.0 * err_max),
        decisions: (cfg.ticks * fleet.len()) as u64,
        switches,
        switch_load,
        switch_degradation,
        holds,
        devices_switched:
            per_device_switches.iter().filter(|&&s| s > 0).count(),
        max_switches_per_device:
            per_device_switches.iter().copied().max().unwrap_or(0),
        regret_events,
        regret_mean_pct: r3(100.0 * regret_mean),
        regret_max_pct: r3(100.0 * regret_max),
        regret_zero_share: r3(zero as f64 / regret_events.max(1) as f64),
        deploy_faults,
        cache_builds: stats.builds,
        cache_hits: stats.hits,
        cache_bench_lookups: regret_events as u64,
        cache_evictions: stats.evictions,
        candidates_enumerated: stats.candidates_enumerated,
        delta_updated: correction.updated,
        delta_points_touched: correction.points_touched,
        delta_rebuild_points: correction.rebuild_points,
        idempotent_reapply_updates,
        post_correction_builds,
        resident_bytes,
        mem_budget_per_cohort,
        rollup_regret,
        telemetry_resident_bytes,
        control_plane,
    })
}

/// The complete report as one JSON value (the golden-pinned payload).
pub fn report_json(r: &FleetBenchReport) -> Value {
    let p = &r.cfg.fleet.population;
    let t = &r.cfg.fleet.transfer;
    let config = json::obj(vec![
        ("devices", json::num(p.size as f64)),
        ("seed", json::num(p.seed as f64)),
        ("family", json::s(&r.cfg.family)),
        ("objective", json::s(&objective_label(r.cfg.objective))),
        ("lut_runs", json::num(r.cfg.fleet.lut_runs as f64)),
        ("noise_sigma", json::num(r.cfg.fleet.noise_sigma)),
        ("flops_log_spread", json::num(p.flops_log_spread)),
        ("bw_log_spread", json::num(p.bw_log_spread)),
        ("thermal_log_spread", json::num(p.thermal_log_spread)),
        ("mem_log_spread", json::num(p.mem_log_spread)),
        ("latent_log_spread", json::num(p.latent_log_spread)),
        ("npu_drop_prob", json::num(p.npu_drop_prob)),
        ("confidence_threshold", json::num(t.confidence_threshold)),
        ("probes_per_engine", json::num(t.probes_per_engine as f64)),
        ("frontier_cache_cap",
         json::num(r.cfg.fleet.frontier_cache_cap as f64)),
        ("frontier_mem_budget_bytes",
         json::num(r.cfg.fleet.frontier_mem_budget_bytes as f64)),
        ("ticks", json::num(r.cfg.ticks as f64)),
        ("tick_ms", json::num(r.cfg.tick_ms)),
    ]);
    let archetypes = json::obj(
        r.archetype_counts
            .iter()
            .map(|&(name, n)| (name, json::num(n as f64)))
            .collect(),
    );
    let population = json::obj(vec![
        ("archetypes", archetypes),
        ("npu_dropped", json::num(r.npu_dropped as f64)),
        ("cohorts", json::num(r.cohorts.len() as f64)),
    ]);
    let transfer = json::obj(vec![
        ("probed_cohorts", json::num(r.probed_cohorts as f64)),
        ("probe_measurements", json::num(r.probe_measurements as f64)),
        ("pred_err_mean_pct", json::num(r.pred_err_mean_pct)),
        ("pred_err_max_pct", json::num(r.pred_err_max_pct)),
    ]);
    let cohorts = Value::Arr(
        r.cohorts
            .iter()
            .map(|c| {
                json::obj(vec![
                    ("id", json::s(&c.id)),
                    ("members", json::num(c.members as f64)),
                    ("probed", Value::Bool(c.probed)),
                    ("min_confidence", json::num(r3(c.min_confidence))),
                    ("builds", json::num(c.builds as f64)),
                    ("hits", json::num(c.hits as f64)),
                ])
            })
            .collect(),
    );
    let holds = json::obj(vec![
        ("not_due", json::num(r.holds.not_due as f64)),
        ("cooldown", json::num(r.holds.cooldown as f64)),
        ("no_trigger", json::num(r.holds.no_trigger as f64)),
        ("no_alternative", json::num(r.holds.no_alternative as f64)),
        ("current_still_best",
         json::num(r.holds.current_still_best as f64)),
        ("below_hysteresis", json::num(r.holds.below_hysteresis as f64)),
    ]);
    let storm = json::obj(vec![
        ("ticks", json::num(r.cfg.ticks as f64)),
        ("decisions", json::num(r.decisions as f64)),
        ("switches", json::num(r.switches as f64)),
        ("switch_load", json::num(r.switch_load as f64)),
        ("switch_degradation", json::num(r.switch_degradation as f64)),
        ("holds", holds),
        ("devices_switched", json::num(r.devices_switched as f64)),
        ("max_switches_per_device",
         json::num(r.max_switches_per_device as f64)),
    ]);
    let regret = json::obj(vec![
        ("events", json::num(r.regret_events as f64)),
        ("mean_pct", json::num(r.regret_mean_pct)),
        ("max_pct", json::num(r.regret_max_pct)),
        ("zero_share", json::num(r.regret_zero_share)),
        ("deploy_faults", json::num(r.deploy_faults as f64)),
    ]);
    let delta = json::obj(vec![
        ("engine", json::s(CORRECTION_ENGINE.name())),
        ("factor", json::num(CORRECTION_FACTOR)),
        ("updated", json::num(r.delta_updated as f64)),
        ("points_touched", json::num(r.delta_points_touched as f64)),
        ("rebuild_points", json::num(r.delta_rebuild_points as f64)),
        ("delta_lt_rebuild",
         Value::Bool(r.delta_points_touched < r.delta_rebuild_points)),
        ("idempotent_reapply_updates",
         json::num(r.idempotent_reapply_updates as f64)),
        ("post_correction_builds",
         json::num(r.post_correction_builds as f64)),
    ]);
    let total = r.cache_builds + r.cache_hits;
    let cache = json::obj(vec![
        ("builds", json::num(r.cache_builds as f64)),
        ("hits", json::num(r.cache_hits as f64)),
        ("bench_lookups", json::num(r.cache_bench_lookups as f64)),
        ("evictions", json::num(r.cache_evictions as f64)),
        ("hit_rate",
         json::num(r3(r.cache_hits as f64 / total.max(1) as f64))),
        ("builds_lt_devices",
         Value::Bool(r.cache_builds < p.size as u64)),
        ("resident_bytes", json::num(r.resident_bytes as f64)),
        ("mem_budget_per_cohort",
         json::num(r.mem_budget_per_cohort as f64)),
        ("under_budget",
         Value::Bool(r.resident_bytes
                     <= r.mem_budget_per_cohort
                         * r.cohorts.len() as u64)),
        ("candidates_enumerated",
         json::num(r.candidates_enumerated as f64)),
        ("decisions_per_sec_amortized",
         json::num(r3(r.decisions as f64 * 1e9
                      / (SIM_NS_PER_EVAL as f64
                         * r.candidates_enumerated.max(1) as f64)))),
    ]);
    let rc = RolloutConfig::default();
    let cp = &r.control_plane;
    let rollout = json::obj(vec![
        ("engine", json::s(ROLLOUT_ENGINE.name())),
        ("ladder",
         Value::Arr(rc.ladder.iter().map(|&n| json::num(n as f64))
             .collect())),
        ("min_samples", json::num(rc.min_samples as f64)),
        ("max_regret_delta_pct", json::num(rc.max_regret_delta_pct)),
        ("max_slo_miss_delta", json::num(rc.max_slo_miss_delta)),
        ("max_fault_delta", json::num(rc.max_fault_delta)),
        ("slo_ms", json::num(r3(ROLLOUT_SLO_MS))),
        ("baseline_samples", json::num(cp.baseline_samples as f64)),
        ("bad_revision", json::num(cp.bad_revision as f64)),
        ("bad_factor", json::num(ROLLOUT_BAD_FACTOR)),
        ("bad_stage", json::s(&cp.bad_stage)),
        ("bad_reason", json::s(&cp.bad_reason)),
        ("bad_canary_regret_pct", json::num(cp.bad_canary_regret_pct)),
        ("bad_control_regret_pct", json::num(cp.bad_control_regret_pct)),
        ("bad_live_cohorts", json::num(cp.bad_live_cohorts as f64)),
        ("rollback_fingerprints_match",
         Value::Bool(cp.rollback_fingerprints_match)),
        ("good_revision", json::num(cp.good_revision as f64)),
        ("good_factor", json::num(ROLLOUT_GOOD_FACTOR)),
        ("good_stage", json::s(&cp.good_stage)),
        ("good_rounds", json::num(cp.good_rounds as f64)),
        ("good_live_cohorts", json::num(cp.good_live_cohorts as f64)),
        ("duplicates_rejected", json::num(cp.duplicates_rejected as f64)),
        ("lookups", json::num(cp.lookups as f64)),
    ]);
    let feedback = json::obj(vec![
        ("rounds", json::num(cp.feedback_rounds as f64)),
        ("samples", json::num(cp.feedback_samples as f64)),
        ("corrections", json::num(cp.feedback_corrections as f64)),
        ("mean_abs_ln",
         Value::Arr(cp.residual_mean_abs_ln.iter().map(|&v| json::num(v))
             .collect())),
        ("delta_updated", json::num(cp.feedback_delta_updated as f64)),
        ("delta_points_touched",
         json::num(cp.feedback_delta_points_touched as f64)),
        ("delta_rebuild_points",
         json::num(cp.feedback_delta_rebuild_points as f64)),
        ("re_anchor_threshold",
         json::num(FeedbackConfig::default().re_anchor_threshold)),
        ("re_anchored_cohorts", json::num(cp.re_anchored_cohorts as f64)),
        ("post_feedback_builds",
         json::num(cp.post_feedback_builds as f64)),
        ("pre_regret_mean_pct", json::num(r.regret_mean_pct)),
        ("post_regret_mean_pct", json::num(cp.post_regret_mean_pct)),
        ("post_regret_max_pct", json::num(cp.post_regret_max_pct)),
        ("post_deploy_faults", json::num(cp.post_deploy_faults as f64)),
        ("regret_improved", Value::Bool(cp.regret_improved)),
    ]);
    json::obj(vec![(
        "fleet_bench",
        json::obj(vec![
            ("config", config),
            ("population", population),
            ("transfer", transfer),
            ("cohorts", cohorts),
            ("storm", storm),
            ("regret", regret),
            ("delta", delta),
            ("cache", cache),
            ("rollout", rollout),
            ("feedback", feedback),
        ]),
    )])
}

/// Print the fleet table; also emit the report as a JSON line and, when
/// `json_out` is given, write it to that file.  With `trace_out`, the
/// whole run is flight-recorded and exported as JSON-lines at that path
/// plus Chrome trace-event JSON (Perfetto-loadable) at
/// `<trace_out>.chrome.json`.
pub fn print(registry: &Registry, cfg: &FleetBenchConfig,
             json_out: Option<&str>, trace_out: Option<&str>) -> Result<()> {
    let recorder = trace_out.map(|_| Arc::new(FlightRecorder::new()));
    let r = run_traced(registry, cfg, recorder.as_ref())?;
    println!("FLEET-BENCH — {} devices, {} cohorts, transferred LUTs vs \
              full-profile oracle",
             r.cfg.fleet.population.size, r.cohorts.len());
    println!("{:<38} {:>7} {:>6} {:>6} {:>7} {:>6}",
             "cohort", "members", "probed", "conf", "builds", "hits");
    println!("{}", super::rule(80));
    for c in &r.cohorts {
        println!("{:<38} {:>7} {:>6} {:>6.3} {:>7} {:>6}",
                 c.id, c.members, if c.probed { "yes" } else { "no" },
                 c.min_confidence, c.builds, c.hits);
    }
    println!("transfer: {} probed cohorts, {} probe measurements, \
              family pred err mean {:.3}% max {:.3}%",
             r.probed_cohorts, r.probe_measurements, r.pred_err_mean_pct,
             r.pred_err_max_pct);
    println!("storm: {} decisions, {} switches ({} load / {} degradation), \
              {} devices switched, max {} per device",
             r.decisions, r.switches, r.switch_load, r.switch_degradation,
             r.devices_switched, r.max_switches_per_device);
    println!("regret vs oracle: mean {:.3}% max {:.3}% over {} events \
              ({:.1}% zero-regret, {} deploy faults)",
             r.regret_mean_pct, r.regret_max_pct, r.regret_events,
             100.0 * r.regret_zero_share, r.deploy_faults);
    println!("cohort caches: {} builds, {} hits ({} of the lookups are \
              bench regret instrumentation), {} evictions \
              (builds < devices: {})",
             r.cache_builds, r.cache_hits, r.cache_bench_lookups,
             r.cache_evictions,
             r.cache_builds < r.cfg.fleet.population.size as u64);
    println!("incremental correction ({} x{:.2}): {} frontiers carried in \
              place, {} points touched vs {} rebuild candidates, \
              {} re-apply updates, {} post-correction builds",
             CORRECTION_ENGINE.name(), CORRECTION_FACTOR, r.delta_updated,
             r.delta_points_touched, r.delta_rebuild_points,
             r.idempotent_reapply_updates, r.post_correction_builds);
    println!("memory: {} resident bytes across {} cohort caches \
              ({} B budget per cohort)",
             r.resident_bytes, r.cohorts.len(), r.mem_budget_per_cohort);
    let cp = &r.control_plane;
    println!("rollout: bad revision {} ({} x{:.2}) {} at canary \
              ({}; treated {:.3}% vs control {:.3}%, {} live, \
              fingerprints restored: {}); good revision {} ({} x{:.2}) \
              {} fleet-wide in {} rounds ({} cohorts live); \
              {} duplicate report(s) rejected, {} lookups",
             cp.bad_revision, ROLLOUT_ENGINE.name(), ROLLOUT_BAD_FACTOR,
             cp.bad_stage, cp.bad_reason, cp.bad_canary_regret_pct,
             cp.bad_control_regret_pct, cp.bad_live_cohorts,
             cp.rollback_fingerprints_match, cp.good_revision,
             ROLLOUT_ENGINE.name(), ROLLOUT_GOOD_FACTOR, cp.good_stage,
             cp.good_rounds, cp.good_live_cohorts, cp.duplicates_rejected,
             cp.lookups);
    println!("feedback: {} rounds, {} residuals, {} corrections, \
              mean |ln| {:?}; {} cohorts re-anchored \
              ({} closing-round rebuilds), regret {:.3}% -> {:.3}% \
              (improved: {}, {} deploy faults)",
             cp.feedback_rounds, cp.feedback_samples,
             cp.feedback_corrections, cp.residual_mean_abs_ln,
             cp.re_anchored_cohorts, cp.post_feedback_builds,
             r.regret_mean_pct, cp.post_regret_mean_pct,
             cp.regret_improved, cp.post_deploy_faults);
    if let Some(s) = &r.rollup_regret {
        println!("telemetry rollup: regret p50 {:.3}% p99 {:.3}% max {:.3}% \
                  over {} samples merged from {} cohort sinks \
                  ({} B resident)",
                 s.median, s.p99, s.max, s.n, r.cohorts.len(),
                 r.telemetry_resident_bytes);
    }
    if let (Some(path), Some(rec)) = (trace_out, &recorder) {
        std::fs::write(path, rec.to_jsonl())
            .with_context(|| format!("writing {path}"))?;
        let chrome = format!("{path}.chrome.json");
        std::fs::write(&chrome, rec.to_chrome_trace())
            .with_context(|| format!("writing {chrome}"))?;
        println!("trace: {} events ({} dropped) to {path}; Chrome trace \
                  to {chrome}",
                 rec.len(), rec.dropped());
    }
    let payload = report_json(&r);
    let line = json::to_string(&payload);
    println!("FLEETBENCH_JSON {line}");
    if let Some(path) = json_out {
        std::fs::write(path, &line)
            .with_context(|| format!("writing {path}"))?;
        println!("JSON written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storm_phases_cover_every_tick() {
        assert_eq!(storm_phase(0), "calm");
        assert_eq!(storm_phase(3), "gpu_surge");
        assert_eq!(storm_phase(7), "npu_throttle");
        assert_eq!(storm_phase(11), "recovery");
    }

    #[test]
    fn storm_conditions_on_bucket_centres() {
        let c = storm_conditions(4, 0, true);
        assert_eq!(c.load(EngineKind::Gpu), 1.0);
        let c = storm_conditions(4, 1, true);
        assert_eq!(c.load(EngineKind::Gpu), 0.0);
        let c = storm_conditions(8, 0, true);
        assert_eq!(c.thermal_scale(EngineKind::Npu), 0.5);
        let c = storm_conditions(8, 0, false);
        assert_eq!(c.load(EngineKind::Cpu), 1.0);
    }
}
