//! Fleet benchmark (`oodin fleet-bench`): population-scale adaptation with
//! transferred LUTs and cohort-shared frontier caches, judged against a
//! full-profile oracle.
//!
//! The driver builds a seeded device fleet ([`crate::fleet`]), transfers
//! one LUT per cohort, then replays a scripted condition storm (calm →
//! GPU surge → NPU thermal wave → recovery) through one
//! [`crate::manager::RuntimeManager`] per device — every manager pointed
//! at its cohort's representative profile, transferred LUT and *shared*
//! frontier cache.  It reports:
//!
//! * **decision regret** — at sampled storm ticks, the transferred-LUT
//!   selection (cohort frontier walk) is re-scored under the device's
//!   *true* measured LUT and compared with the full-profile oracle's
//!   selection (complete search over the true LUT at the exact
//!   conditions).  Regret is the relative true-latency excess;
//! * **cohort cache effectiveness** — frontier builds vs hits across the
//!   population (builds scale with cohorts × visited buckets, not with
//!   devices);
//! * **per-device adaptation decisions** — switches and hold reasons from
//!   the real manager state machine under the storm.
//!
//! The smoke configuration (200 devices, zero measurement noise) is
//! byte-stable and golden-pinned (`tests/golden/fleetbench_smoke.json`),
//! regenerated independently by the Python oracle
//! `python/golden_fleetbench.py` — same N-version convention as
//! `opt-bench` and `serve-bench`.

use anyhow::{ensure, Context, Result};

use crate::designspace::{rank, ConditionsBucket, DesignSpace, LutDelta};
use crate::device::EngineKind;
use crate::fleet::{Fleet, FleetConfig, PopulationConfig};
use crate::manager::{adjusted_latency, Conditions, Decision, HoldReason,
                     Reason, RuntimeManager};
use crate::measurements::Lut;
use crate::model::Registry;
use crate::optimizer::{Objective, SearchSpace};
use crate::perf;
use crate::telemetry::trace::FlightRecorder;
use crate::util::json::{self, Value};
use crate::util::stats::{LatencyStats, Percentile};

use std::sync::Arc;

use super::optbench::{objective_label, SIM_NS_PER_EVAL};
use super::r3;

/// Engine of the fleet-wide online correction replayed after the storm
/// (the probe-fallback shape: one uniform per-engine latency factor).
pub const CORRECTION_ENGINE: EngineKind = EngineKind::Cpu;
/// Uniform latency factor of that correction.
pub const CORRECTION_FACTOR: f64 = 1.25;

/// Experiment dimensions and depth.
#[derive(Debug, Clone)]
pub struct FleetBenchConfig {
    /// Fleet construction parameters (population, transfer, LUT depth).
    pub fleet: FleetConfig,
    /// Model family every device's app is built around.
    pub family: String,
    /// Per-app objective.
    pub objective: Objective,
    /// Storm length in manager ticks.
    pub ticks: usize,
    /// Milliseconds between ticks (the manager check interval).
    pub tick_ms: f64,
    /// Ticks at which regret is evaluated against the oracle.
    pub regret_ticks: Vec<usize>,
    /// When set, `run` fails if mean regret exceeds this many percent.
    pub enforce_regret_pct: Option<f64>,
}

impl FleetBenchConfig {
    /// The CI-sized, golden-pinned configuration: 200 devices, zero
    /// measurement noise (every latency is the closed-form roofline
    /// prediction), regret enforced at ≤ 5%.
    pub fn smoke() -> Self {
        FleetBenchConfig {
            fleet: FleetConfig::default(),
            family: "mobilenet_v2_100".to_string(),
            objective: Objective::MinLatency {
                stat: Percentile::Avg,
                epsilon: 0.05,
            },
            ticks: 12,
            tick_ms: 250.0,
            regret_ticks: vec![1, 4, 8, 11],
            enforce_regret_pct: Some(5.0),
        }
    }

    /// The full sweep: a 1000-device fleet with realistic measurement
    /// noise (not golden-pinned).
    pub fn full() -> Self {
        let mut cfg = FleetBenchConfig::smoke();
        cfg.fleet.population = PopulationConfig {
            size: 1000,
            ..PopulationConfig::default()
        };
        cfg.fleet.lut_runs = 20;
        cfg.fleet.lut_warmup = 2;
        cfg.fleet.noise_sigma = 0.02;
        cfg.fleet.transfer.noise_sigma = 0.02;
        cfg.enforce_regret_pct = None;
        cfg
    }
}

/// Storm phase label of a tick.
pub fn storm_phase(tick: usize) -> &'static str {
    match tick {
        0..=2 => "calm",
        3..=6 => "gpu_surge",
        7..=9 => "npu_throttle",
        _ => "recovery",
    }
}

/// Scripted per-device conditions at a storm tick.  Loads sit on
/// conditions-bucket centres (exact powers of two) so the smoke report
/// stays closed-form.
pub fn storm_conditions(tick: usize, device_idx: usize, has_npu: bool)
                        -> Conditions {
    let mut c = Conditions::idle();
    match storm_phase(tick) {
        "gpu_surge" => {
            if device_idx % 2 == 0 {
                c.loads.insert(EngineKind::Gpu, 1.0);
            }
        }
        "npu_throttle" => {
            if has_npu {
                c.thermal.insert(EngineKind::Npu, 0.5);
            } else {
                c.loads.insert(EngineKind::Cpu, 1.0);
            }
        }
        _ => {}
    }
    c
}

/// Hold-reason histogram over every manager tick.
#[derive(Debug, Clone, Copy, Default)]
pub struct HoldCounts {
    /// Check interval not elapsed.
    pub not_due: u64,
    /// Post-switch quiet period.
    pub cooldown: u64,
    /// Stable conditions, nothing to react to.
    pub no_trigger: u64,
    /// Trigger fired but no feasible alternative.
    pub no_alternative: u64,
    /// Re-search picked the running design.
    pub current_still_best: u64,
    /// Alternative won by less than the hysteresis margin.
    pub below_hysteresis: u64,
}

/// One cohort's summary row in the report.
#[derive(Debug, Clone)]
pub struct CohortRow {
    /// Canonical cohort id.
    pub id: String,
    /// Member device count.
    pub members: usize,
    /// True when any engine ran the probe fallback.
    pub probed: bool,
    /// Lowest per-engine transfer confidence (worst member).
    pub min_confidence: f64,
    /// Frontier builds charged to this cohort's shared cache.
    pub builds: u64,
    /// Frontier hits served by this cohort's shared cache.
    pub hits: u64,
}

/// The aggregated fleet-bench report.
#[derive(Debug)]
pub struct FleetBenchReport {
    /// The configuration the report was produced under.
    pub cfg: FleetBenchConfig,
    /// Devices per archetype, in sampling order.
    pub archetype_counts: Vec<(&'static str, usize)>,
    /// Units whose NPU was dropped by the availability axis.
    pub npu_dropped: usize,
    /// Per-cohort summary rows.
    pub cohorts: Vec<CohortRow>,
    /// Cohorts that ran the probe fallback.
    pub probed_cohorts: usize,
    /// Probe configurations measured across the fleet.
    pub probe_measurements: usize,
    /// Mean |predicted − true|/true over the family's LUT entries (%).
    pub pred_err_mean_pct: f64,
    /// Worst such error (%).
    pub pred_err_max_pct: f64,
    /// Manager decisions taken (ticks × devices).
    pub decisions: u64,
    /// Reconfigurations issued.
    pub switches: u64,
    /// Switches triggered by load change.
    pub switch_load: u64,
    /// Switches triggered by confirmed degradation.
    pub switch_degradation: u64,
    /// Hold-reason histogram.
    pub holds: HoldCounts,
    /// Devices that switched at least once.
    pub devices_switched: usize,
    /// Largest per-device switch count.
    pub max_switches_per_device: u64,
    /// Regret samples evaluated (regret ticks × devices).
    pub regret_events: usize,
    /// Mean regret (%).
    pub regret_mean_pct: f64,
    /// Worst regret (%).
    pub regret_max_pct: f64,
    /// Fraction of events with (near-)zero regret.
    pub regret_zero_share: f64,
    /// Transferred selections inadmissible under the device's true
    /// memory/deployability filters.
    pub deploy_faults: u64,
    /// Frontier builds across every cohort cache.
    pub cache_builds: u64,
    /// Frontier hits across every cohort cache.
    pub cache_hits: u64,
    /// Cache lookups made by the bench's own regret instrumentation (one
    /// per regret event) — included in `cache_builds`/`cache_hits`, broken
    /// out so the adaptation-path rate can be read separately.
    pub cache_bench_lookups: u64,
    /// LRU evictions across every cohort cache.
    pub cache_evictions: u64,
    /// Candidates enumerated by frontier builds across every cohort cache
    /// (the amortised decision cost the rate below is computed from).
    pub candidates_enumerated: u64,
    /// Cohort-cache frontiers carried in place by the post-storm
    /// per-engine correction.
    pub delta_updated: u64,
    /// Frontier points the correction's delta path touched.
    pub delta_points_touched: u64,
    /// Candidates full rebuilds of the same frontiers would have scored.
    pub delta_rebuild_points: u64,
    /// Frontiers updated when every device's manager re-applied the same
    /// correction to its cohort-shared cache (must be 0: idempotent).
    pub idempotent_reapply_updates: u64,
    /// Frontier builds during the post-correction idle round (must be 0:
    /// the correction keeps every visited bucket warm).
    pub post_correction_builds: u64,
    /// Accounted resident bytes across every cohort cache.
    pub resident_bytes: u64,
    /// Byte budget each cohort cache runs under
    /// ([`FleetConfig::frontier_mem_budget_bytes`] split evenly).
    pub mem_budget_per_cohort: u64,
    /// Fleet-wide regret distribution (%) from the per-cohort telemetry
    /// rollup — bounded log-scaled histograms merged across every cohort
    /// sink; `None` when no regret ticks ran.
    pub rollup_regret: Option<LatencyStats>,
    /// Bytes resident across every cohort telemetry sink (constant in
    /// sample count).
    pub telemetry_resident_bytes: usize,
}

/// The full-profile oracle's selection: complete search over the device's
/// true LUT at the *exact* observed conditions.
fn oracle_pick(fleet: &Fleet, device_idx: usize, true_lut: &Lut,
               objective: Objective, space: &SearchSpace,
               conds: &Conditions)
               -> Result<crate::designspace::Candidate> {
    let ds = DesignSpace::new(&fleet.devices[device_idx].profile,
                              &fleet.registry, true_lut);
    let ranked = rank(ds.enumerate(objective, space, conds), objective);
    ranked.into_iter().next().with_context(|| {
        format!("{}: oracle found no feasible design",
                fleet.devices[device_idx].id)
    })
}

/// Run the fleet benchmark.
pub fn run(registry: &Registry, cfg: &FleetBenchConfig)
           -> Result<FleetBenchReport> {
    run_traced(registry, cfg, None)
}

/// [`run`] with an optional flight recorder: cohort-transfer provenance,
/// every frontier-cache transition, every per-device decide outcome and
/// the post-storm correction land in the trace, stamped with the storm's
/// deterministic virtual clock (µs = tick × tick_ms × 1000).  Recording
/// never changes a decision, a cache counter, or the report.
pub fn run_traced(registry: &Registry, cfg: &FleetBenchConfig,
                  recorder: Option<&Arc<FlightRecorder>>)
                  -> Result<FleetBenchReport> {
    let mut fleet = Fleet::build(std::sync::Arc::new(registry.clone()),
                                 cfg.fleet.clone())?;
    if let Some(rec) = recorder {
        rec.set_now_us(0);
        fleet.attach_recorder(rec);
    }
    let space = SearchSpace::family(&cfg.family);
    let objective = cfg.objective;

    // Population summary.
    let mut archetype_counts: Vec<(&'static str, usize)> =
        crate::fleet::population::ARCHETYPES
            .iter()
            .map(|&a| (a, 0usize))
            .collect();
    let mut npu_dropped = 0usize;
    for d in &fleet.devices {
        if let Some(c) = archetype_counts.iter_mut().find(|c| c.0 == d.archetype)
        {
            c.1 += 1;
        }
        if d.dropped_npu {
            npu_dropped += 1;
        }
    }

    // Full-profile oracle LUTs (what per-device profiling would have
    // produced) and the transfer prediction error against them.
    let mut oracle_luts = Vec::with_capacity(fleet.len());
    let mut err_sum = 0.0;
    let mut err_max = 0.0f64;
    let mut err_n = 0usize;
    for idx in 0..fleet.len() {
        let true_lut = fleet.oracle_lut(idx)?;
        let cohort = fleet.cohort_of(idx);
        for (k, pred) in &cohort.lut.entries {
            let fam = &registry
                .get(&k.variant)
                .with_context(|| format!("variant {}", k.variant))?
                .family;
            if fam != &cfg.family {
                continue;
            }
            let truth = true_lut
                .get(k)
                .with_context(|| format!("{}: oracle missing {}",
                                         fleet.devices[idx].id, k.id()))?;
            let err = (pred.latency.avg / truth.latency.avg - 1.0).abs();
            err_sum += err;
            err_max = err_max.max(err);
            err_n += 1;
        }
        oracle_luts.push(true_lut);
    }

    // One RuntimeManager per device over the cohort-shared state.
    let mut managers: Vec<RuntimeManager> = Vec::with_capacity(fleet.len());
    for idx in 0..fleet.len() {
        let mut m = fleet.manager_for(idx, objective, &space)?;
        if let Some(rec) = recorder {
            m = m.with_recorder(Arc::clone(rec), &fleet.devices[idx].id);
        }
        managers.push(m);
    }

    // The storm.
    let mut holds = HoldCounts::default();
    let mut switches = 0u64;
    let mut switch_load = 0u64;
    let mut switch_degradation = 0u64;
    let mut per_device_switches = vec![0u64; fleet.len()];
    let mut regrets: Vec<f64> = Vec::new();
    let mut deploy_faults = 0u64;
    for tick in 0..cfg.ticks {
        let now_ms = tick as f64 * cfg.tick_ms;
        if let Some(rec) = recorder {
            rec.set_now_us((now_ms * 1000.0) as u64);
        }
        let regret_tick = cfg.regret_ticks.contains(&tick);
        for idx in 0..fleet.len() {
            let has_npu = fleet.devices[idx].has_npu();
            let conds = storm_conditions(tick, idx, has_npu);
            let sink = Arc::clone(&fleet.cohort_of(idx).telemetry);
            sink.incr("decisions");
            match managers[idx].decide(now_ms, &conds) {
                Decision::Switch(sw) => {
                    sink.incr("switches");
                    switches += 1;
                    per_device_switches[idx] += 1;
                    match sw.reason {
                        Reason::LoadChange => switch_load += 1,
                        Reason::Degradation => switch_degradation += 1,
                    }
                }
                Decision::Hold(h) => match h {
                    HoldReason::NotDue => holds.not_due += 1,
                    HoldReason::Cooldown { .. } => holds.cooldown += 1,
                    HoldReason::NoTrigger => holds.no_trigger += 1,
                    HoldReason::NoAlternative => holds.no_alternative += 1,
                    HoldReason::CurrentStillBest => {
                        holds.current_still_best += 1
                    }
                    HoldReason::BelowHysteresis { .. } => {
                        holds.below_hysteresis += 1
                    }
                },
            }
            if regret_tick {
                let sel = fleet.select(idx, objective, &space, &conds)?;
                // In-binary exactness re-check: the cohort frontier walk
                // must equal a full search over the cohort LUT at the
                // bucket's representative conditions.
                let bucket = ConditionsBucket::of(&conds);
                let cohort = fleet.cohort_of(idx);
                let ds = DesignSpace::new(&cohort.rep, &fleet.registry,
                                          &cohort.lut);
                let full = rank(
                    ds.enumerate(objective, &space, &bucket.representative()),
                    objective,
                );
                ensure!(
                    full.first().map(|c| &c.design) == Some(&sel),
                    "{}@t{}: frontier walk diverged from full search",
                    fleet.devices[idx].id, tick
                );

                let true_lut = &oracle_luts[idx];
                let oracle = oracle_pick(&fleet, idx, true_lut, objective,
                                         &space, &conds)?;
                let sel_adj = adjusted_latency(true_lut, &sel,
                                               objective.stat(), &conds)
                    .with_context(|| format!("{}: transferred pick absent \
                                              from the true LUT",
                                             fleet.devices[idx].id))?;
                let oracle_adj = adjusted_latency(true_lut, &oracle.design,
                                                  objective.stat(), &conds)
                    .context("oracle pick absent from the true LUT")?;
                let entry = true_lut.get(&sel.lut_key()).unwrap();
                let v = registry.get(&sel.variant).unwrap();
                let admissible =
                    perf::fits_memory(&fleet.devices[idx].profile, v)
                        && entry.latency.avg
                            <= fleet.devices[idx].profile
                                .max_deployable_latency_ms;
                let r = sel_adj / oracle_adj - 1.0;
                // An inadmissible pick can undercut the (feasible-only)
                // oracle; clamping its regret at 0 keeps the headline mean
                // from being flattered by deployability faults — the fault
                // counter, not a negative regret, is their signal.
                let rv = if admissible {
                    r
                } else {
                    deploy_faults += 1;
                    r.max(0.0)
                };
                regrets.push(rv);
                sink.record("regret_pct", 100.0 * rv);
            }
        }
    }

    let regret_events = regrets.len();
    let regret_sum: f64 = regrets.iter().sum();
    let regret_mean = regret_sum / regret_events.max(1) as f64;
    let regret_max = regrets.iter().fold(0.0f64, |a, &b| a.max(b));
    let zero = regrets.iter().filter(|&&r| r <= 1e-12).count();

    let stats = fleet.cache_stats();
    // The acceptance-criteria ensures are tied to the regret enforcement:
    // ad-hoc invocations (e.g. `--smoke --devices 20`, where the cohort
    // count can approach the device count) are reported, not aborted.
    if let Some(limit) = cfg.enforce_regret_pct {
        ensure!(
            stats.builds < fleet.len() as u64,
            "cohort sharing must amortise: {} frontier builds for {} devices",
            stats.builds, fleet.len()
        );
        ensure!(
            100.0 * regret_mean <= limit,
            "mean transferred-LUT regret {:.3}% exceeds the {limit}% bound",
            100.0 * regret_mean
        );
    }

    let cohorts: Vec<CohortRow> = fleet
        .cohorts
        .iter()
        .map(|c| {
            let s = c.cache_stats();
            CohortRow {
                id: c.id.clone(),
                members: c.members.len(),
                probed: c.probed(),
                min_confidence: c.min_confidence(),
                builds: s.builds,
                hits: s.hits,
            }
        })
        .collect();
    let probed_cohorts = fleet.cohorts.iter().filter(|c| c.probed()).count();
    let probe_measurements: usize = fleet
        .cohorts
        .iter()
        .flat_map(|c| c.transfer.values())
        .map(|t| t.probes)
        .sum();

    // -- post-storm online correction through the incremental delta path --
    // The probe-fallback shape at fleet scale: every cohort's CPU rows 25%
    // slower.  Cohort caches must be carried in place (no cold starts),
    // per-manager re-application must be idempotent on the shared caches,
    // and a follow-up idle round must be served entirely from warm
    // frontiers.
    if let Some(rec) = recorder {
        rec.set_now_us((cfg.ticks as f64 * cfg.tick_ms * 1000.0) as u64);
    }
    let delta = LutDelta::engine_scale(CORRECTION_ENGINE, CORRECTION_FACTOR);
    let correction =
        fleet.apply_engine_correction(CORRECTION_ENGINE, CORRECTION_FACTOR);
    ensure!(correction.dropped == 0,
            "correction dropped {} warm cohort frontiers", correction.dropped);
    ensure!(correction.updated == 0
                || correction.points_touched < correction.rebuild_points,
            "delta path touched {} points but full rebuilds would score \
             only {}",
            correction.points_touched, correction.rebuild_points);
    if cfg.enforce_regret_pct.is_some() {
        ensure!(correction.updated > 0,
                "the smoke storm must leave warm cohort frontiers for the \
                 correction to carry");
    }
    let mut idempotent_reapply_updates = 0u64;
    for idx in 0..fleet.len() {
        let new_lut = std::sync::Arc::clone(&fleet.cohort_of(idx).lut);
        let re = managers[idx].apply_lut_delta(new_lut, &delta);
        ensure!(re.dropped == 0,
                "{}: manager re-apply dropped {} frontiers",
                fleet.devices[idx].id, re.dropped);
        idempotent_reapply_updates += re.updated;
    }
    ensure!(idempotent_reapply_updates == 0,
            "per-manager re-apply must be idempotent on shared caches, \
             updated {idempotent_reapply_updates} frontiers");
    let builds_before = fleet.cache_stats().builds;
    let idle = Conditions::idle();
    for idx in 0..fleet.len() {
        let sel = fleet.select(idx, objective, &space, &idle)?;
        let cohort = fleet.cohort_of(idx);
        let ds = DesignSpace::new(&cohort.rep, &fleet.registry, &cohort.lut);
        let full = rank(ds.enumerate(objective, &space, &idle), objective);
        ensure!(full.first().map(|c| &c.design) == Some(&sel),
                "{}: post-correction frontier walk diverged from full \
                 search",
                fleet.devices[idx].id);
    }
    let post_correction_builds = fleet.cache_stats().builds - builds_before;
    ensure!(post_correction_builds == 0,
            "correction left {post_correction_builds} cohort buckets cold");
    for c in &fleet.cohorts {
        ensure!(c.mem_budget() == 0 || c.resident_bytes() <= c.mem_budget(),
                "{}: resident {} B over the {} B cohort budget",
                c.id, c.resident_bytes(), c.mem_budget());
    }
    let resident_bytes = fleet.resident_bytes();
    let mem_budget_per_cohort =
        fleet.cohorts.first().map(|c| c.mem_budget()).unwrap_or(0);
    let rollup = fleet.rollup();
    let rollup_regret = rollup.stats("regret_pct");
    let telemetry_resident_bytes: usize =
        fleet.cohorts.iter().map(|c| c.telemetry.resident_bytes()).sum();

    Ok(FleetBenchReport {
        cfg: cfg.clone(),
        archetype_counts,
        npu_dropped,
        cohorts,
        probed_cohorts,
        probe_measurements,
        pred_err_mean_pct: r3(100.0 * err_sum / err_n.max(1) as f64),
        pred_err_max_pct: r3(100.0 * err_max),
        decisions: (cfg.ticks * fleet.len()) as u64,
        switches,
        switch_load,
        switch_degradation,
        holds,
        devices_switched:
            per_device_switches.iter().filter(|&&s| s > 0).count(),
        max_switches_per_device:
            per_device_switches.iter().copied().max().unwrap_or(0),
        regret_events,
        regret_mean_pct: r3(100.0 * regret_mean),
        regret_max_pct: r3(100.0 * regret_max),
        regret_zero_share: r3(zero as f64 / regret_events.max(1) as f64),
        deploy_faults,
        cache_builds: stats.builds,
        cache_hits: stats.hits,
        cache_bench_lookups: regret_events as u64,
        cache_evictions: stats.evictions,
        candidates_enumerated: stats.candidates_enumerated,
        delta_updated: correction.updated,
        delta_points_touched: correction.points_touched,
        delta_rebuild_points: correction.rebuild_points,
        idempotent_reapply_updates,
        post_correction_builds,
        resident_bytes,
        mem_budget_per_cohort,
        rollup_regret,
        telemetry_resident_bytes,
    })
}

/// The complete report as one JSON value (the golden-pinned payload).
pub fn report_json(r: &FleetBenchReport) -> Value {
    let p = &r.cfg.fleet.population;
    let t = &r.cfg.fleet.transfer;
    let config = json::obj(vec![
        ("devices", json::num(p.size as f64)),
        ("seed", json::num(p.seed as f64)),
        ("family", json::s(&r.cfg.family)),
        ("objective", json::s(&objective_label(r.cfg.objective))),
        ("lut_runs", json::num(r.cfg.fleet.lut_runs as f64)),
        ("noise_sigma", json::num(r.cfg.fleet.noise_sigma)),
        ("flops_log_spread", json::num(p.flops_log_spread)),
        ("bw_log_spread", json::num(p.bw_log_spread)),
        ("thermal_log_spread", json::num(p.thermal_log_spread)),
        ("mem_log_spread", json::num(p.mem_log_spread)),
        ("latent_log_spread", json::num(p.latent_log_spread)),
        ("npu_drop_prob", json::num(p.npu_drop_prob)),
        ("confidence_threshold", json::num(t.confidence_threshold)),
        ("probes_per_engine", json::num(t.probes_per_engine as f64)),
        ("frontier_cache_cap",
         json::num(r.cfg.fleet.frontier_cache_cap as f64)),
        ("frontier_mem_budget_bytes",
         json::num(r.cfg.fleet.frontier_mem_budget_bytes as f64)),
        ("ticks", json::num(r.cfg.ticks as f64)),
        ("tick_ms", json::num(r.cfg.tick_ms)),
    ]);
    let archetypes = json::obj(
        r.archetype_counts
            .iter()
            .map(|&(name, n)| (name, json::num(n as f64)))
            .collect(),
    );
    let population = json::obj(vec![
        ("archetypes", archetypes),
        ("npu_dropped", json::num(r.npu_dropped as f64)),
        ("cohorts", json::num(r.cohorts.len() as f64)),
    ]);
    let transfer = json::obj(vec![
        ("probed_cohorts", json::num(r.probed_cohorts as f64)),
        ("probe_measurements", json::num(r.probe_measurements as f64)),
        ("pred_err_mean_pct", json::num(r.pred_err_mean_pct)),
        ("pred_err_max_pct", json::num(r.pred_err_max_pct)),
    ]);
    let cohorts = Value::Arr(
        r.cohorts
            .iter()
            .map(|c| {
                json::obj(vec![
                    ("id", json::s(&c.id)),
                    ("members", json::num(c.members as f64)),
                    ("probed", Value::Bool(c.probed)),
                    ("min_confidence", json::num(r3(c.min_confidence))),
                    ("builds", json::num(c.builds as f64)),
                    ("hits", json::num(c.hits as f64)),
                ])
            })
            .collect(),
    );
    let holds = json::obj(vec![
        ("not_due", json::num(r.holds.not_due as f64)),
        ("cooldown", json::num(r.holds.cooldown as f64)),
        ("no_trigger", json::num(r.holds.no_trigger as f64)),
        ("no_alternative", json::num(r.holds.no_alternative as f64)),
        ("current_still_best",
         json::num(r.holds.current_still_best as f64)),
        ("below_hysteresis", json::num(r.holds.below_hysteresis as f64)),
    ]);
    let storm = json::obj(vec![
        ("ticks", json::num(r.cfg.ticks as f64)),
        ("decisions", json::num(r.decisions as f64)),
        ("switches", json::num(r.switches as f64)),
        ("switch_load", json::num(r.switch_load as f64)),
        ("switch_degradation", json::num(r.switch_degradation as f64)),
        ("holds", holds),
        ("devices_switched", json::num(r.devices_switched as f64)),
        ("max_switches_per_device",
         json::num(r.max_switches_per_device as f64)),
    ]);
    let regret = json::obj(vec![
        ("events", json::num(r.regret_events as f64)),
        ("mean_pct", json::num(r.regret_mean_pct)),
        ("max_pct", json::num(r.regret_max_pct)),
        ("zero_share", json::num(r.regret_zero_share)),
        ("deploy_faults", json::num(r.deploy_faults as f64)),
    ]);
    let delta = json::obj(vec![
        ("engine", json::s(CORRECTION_ENGINE.name())),
        ("factor", json::num(CORRECTION_FACTOR)),
        ("updated", json::num(r.delta_updated as f64)),
        ("points_touched", json::num(r.delta_points_touched as f64)),
        ("rebuild_points", json::num(r.delta_rebuild_points as f64)),
        ("delta_lt_rebuild",
         Value::Bool(r.delta_points_touched < r.delta_rebuild_points)),
        ("idempotent_reapply_updates",
         json::num(r.idempotent_reapply_updates as f64)),
        ("post_correction_builds",
         json::num(r.post_correction_builds as f64)),
    ]);
    let total = r.cache_builds + r.cache_hits;
    let cache = json::obj(vec![
        ("builds", json::num(r.cache_builds as f64)),
        ("hits", json::num(r.cache_hits as f64)),
        ("bench_lookups", json::num(r.cache_bench_lookups as f64)),
        ("evictions", json::num(r.cache_evictions as f64)),
        ("hit_rate",
         json::num(r3(r.cache_hits as f64 / total.max(1) as f64))),
        ("builds_lt_devices",
         Value::Bool(r.cache_builds < p.size as u64)),
        ("resident_bytes", json::num(r.resident_bytes as f64)),
        ("mem_budget_per_cohort",
         json::num(r.mem_budget_per_cohort as f64)),
        ("under_budget",
         Value::Bool(r.resident_bytes
                     <= r.mem_budget_per_cohort
                         * r.cohorts.len() as u64)),
        ("candidates_enumerated",
         json::num(r.candidates_enumerated as f64)),
        ("decisions_per_sec_amortized",
         json::num(r3(r.decisions as f64 * 1e9
                      / (SIM_NS_PER_EVAL as f64
                         * r.candidates_enumerated.max(1) as f64)))),
    ]);
    json::obj(vec![(
        "fleet_bench",
        json::obj(vec![
            ("config", config),
            ("population", population),
            ("transfer", transfer),
            ("cohorts", cohorts),
            ("storm", storm),
            ("regret", regret),
            ("delta", delta),
            ("cache", cache),
        ]),
    )])
}

/// Print the fleet table; also emit the report as a JSON line and, when
/// `json_out` is given, write it to that file.  With `trace_out`, the
/// whole run is flight-recorded and exported as JSON-lines at that path
/// plus Chrome trace-event JSON (Perfetto-loadable) at
/// `<trace_out>.chrome.json`.
pub fn print(registry: &Registry, cfg: &FleetBenchConfig,
             json_out: Option<&str>, trace_out: Option<&str>) -> Result<()> {
    let recorder = trace_out.map(|_| Arc::new(FlightRecorder::new()));
    let r = run_traced(registry, cfg, recorder.as_ref())?;
    println!("FLEET-BENCH — {} devices, {} cohorts, transferred LUTs vs \
              full-profile oracle",
             r.cfg.fleet.population.size, r.cohorts.len());
    println!("{:<38} {:>7} {:>6} {:>6} {:>7} {:>6}",
             "cohort", "members", "probed", "conf", "builds", "hits");
    println!("{}", super::rule(80));
    for c in &r.cohorts {
        println!("{:<38} {:>7} {:>6} {:>6.3} {:>7} {:>6}",
                 c.id, c.members, if c.probed { "yes" } else { "no" },
                 c.min_confidence, c.builds, c.hits);
    }
    println!("transfer: {} probed cohorts, {} probe measurements, \
              family pred err mean {:.3}% max {:.3}%",
             r.probed_cohorts, r.probe_measurements, r.pred_err_mean_pct,
             r.pred_err_max_pct);
    println!("storm: {} decisions, {} switches ({} load / {} degradation), \
              {} devices switched, max {} per device",
             r.decisions, r.switches, r.switch_load, r.switch_degradation,
             r.devices_switched, r.max_switches_per_device);
    println!("regret vs oracle: mean {:.3}% max {:.3}% over {} events \
              ({:.1}% zero-regret, {} deploy faults)",
             r.regret_mean_pct, r.regret_max_pct, r.regret_events,
             100.0 * r.regret_zero_share, r.deploy_faults);
    println!("cohort caches: {} builds, {} hits ({} of the lookups are \
              bench regret instrumentation), {} evictions \
              (builds < devices: {})",
             r.cache_builds, r.cache_hits, r.cache_bench_lookups,
             r.cache_evictions,
             r.cache_builds < r.cfg.fleet.population.size as u64);
    println!("incremental correction ({} x{:.2}): {} frontiers carried in \
              place, {} points touched vs {} rebuild candidates, \
              {} re-apply updates, {} post-correction builds",
             CORRECTION_ENGINE.name(), CORRECTION_FACTOR, r.delta_updated,
             r.delta_points_touched, r.delta_rebuild_points,
             r.idempotent_reapply_updates, r.post_correction_builds);
    println!("memory: {} resident bytes across {} cohort caches \
              ({} B budget per cohort)",
             r.resident_bytes, r.cohorts.len(), r.mem_budget_per_cohort);
    if let Some(s) = &r.rollup_regret {
        println!("telemetry rollup: regret p50 {:.3}% p99 {:.3}% max {:.3}% \
                  over {} samples merged from {} cohort sinks \
                  ({} B resident)",
                 s.median, s.p99, s.max, s.n, r.cohorts.len(),
                 r.telemetry_resident_bytes);
    }
    if let (Some(path), Some(rec)) = (trace_out, &recorder) {
        std::fs::write(path, rec.to_jsonl())
            .with_context(|| format!("writing {path}"))?;
        let chrome = format!("{path}.chrome.json");
        std::fs::write(&chrome, rec.to_chrome_trace())
            .with_context(|| format!("writing {chrome}"))?;
        println!("trace: {} events ({} dropped) to {path}; Chrome trace \
                  to {chrome}",
                 rec.len(), rec.dropped());
    }
    let payload = report_json(&r);
    let line = json::to_string(&payload);
    println!("FLEETBENCH_JSON {line}");
    if let Some(path) = json_out {
        std::fs::write(path, &line)
            .with_context(|| format!("writing {path}"))?;
        println!("JSON written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storm_phases_cover_every_tick() {
        assert_eq!(storm_phase(0), "calm");
        assert_eq!(storm_phase(3), "gpu_surge");
        assert_eq!(storm_phase(7), "npu_throttle");
        assert_eq!(storm_phase(11), "recovery");
    }

    #[test]
    fn storm_conditions_on_bucket_centres() {
        let c = storm_conditions(4, 0, true);
        assert_eq!(c.load(EngineKind::Gpu), 1.0);
        let c = storm_conditions(4, 1, true);
        assert_eq!(c.load(EngineKind::Gpu), 0.0);
        let c = storm_conditions(8, 0, true);
        assert_eq!(c.thermal_scale(EngineKind::Npu), 0.5);
        let c = storm_conditions(8, 0, false);
        assert_eq!(c.load(EngineKind::Cpu), 1.0);
    }
}
