//! Bounded, deadline-annotated request queue with load-shedding admission
//! and degrade-mode watermarks.
//!
//! This is the pipeline's single source of backpressure truth, shared by
//! the threaded [`Server`](crate::serving::Server) (behind a mutex) and
//! the deterministic virtual-time
//! [`EventPipeline`](crate::serving::pipeline::EventPipeline):
//!
//! * **Bounded**: `admit` refuses (sheds) once `cap` entries wait — the
//!   queue can never exceed its capacity, by construction.
//! * **Deadline-annotated**: every entry carries its arrival and absolute
//!   deadline in microseconds on the caller's timeline, which is what the
//!   deadline-aware batch policy reasons about.
//! * **Degrade watermarks**: crossing `degrade_high` waiting entries flips
//!   the queue into *degraded* mode (serve the cheaper ladder — OODIn's
//!   accuracy-for-latency trade under pressure, the serving-side analogue
//!   of the scheduler's degrade-or-reject admission); draining back to
//!   `degrade_low` flips it back.

use std::collections::VecDeque;

/// One queued request: caller payload + timing metadata (µs on the
/// caller's timeline — wall µs since server start, or virtual µs).
#[derive(Debug, Clone)]
pub struct QueueEntry<T> {
    /// Caller payload (frame + reply channel, or a virtual request).
    pub item: T,
    /// Enqueue instant (µs).
    pub arrival_us: u64,
    /// Absolute completion deadline (µs); `u64::MAX` = none.
    pub deadline_us: u64,
}

/// Admission outcome for an accepted request — the serving-level mirror of
/// the scheduler's degrade-or-reject admission control.  A refused request
/// is returned to the caller as the `Err` arm of
/// [`DeadlineQueue::admit`], counted (never silently dropped).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Admitted {
    /// True when the queue was already in degraded mode at admission.
    pub degraded: bool,
}

/// The bounded deadline queue.
#[derive(Debug)]
pub struct DeadlineQueue<T> {
    cap: usize,
    degrade_high: usize,
    degrade_low: usize,
    entries: VecDeque<QueueEntry<T>>,
    degraded: bool,
    /// Requests refused at capacity.
    pub sheds: u64,
    /// Requests accepted.
    pub admitted: u64,
    /// Times the queue entered degraded mode.
    pub degrade_transitions: u64,
    /// High-water mark of the queue depth ever observed.
    pub max_depth: usize,
}

impl<T> DeadlineQueue<T> {
    /// An empty queue holding at most `cap` entries.  `degrade_high` /
    /// `degrade_low` are the enter/leave watermarks for degraded mode
    /// (`usize::MAX` / `0` disable it).
    pub fn new(cap: usize, degrade_high: usize, degrade_low: usize) -> Self {
        assert!(cap > 0, "queue capacity must be positive");
        assert!(degrade_low <= degrade_high, "watermarks inverted");
        DeadlineQueue {
            cap,
            degrade_high,
            degrade_low,
            entries: VecDeque::new(),
            degraded: false,
            sheds: 0,
            admitted: 0,
            degrade_transitions: 0,
            max_depth: 0,
        }
    }

    /// Number of waiting entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing waits.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Configured capacity bound.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// True while the queue is above the degrade watermarks — batches
    /// should launch from the degraded (cheaper) ladder.
    pub fn degraded(&self) -> bool {
        self.degraded
    }

    /// Arrival instant of the oldest waiting entry.
    pub fn oldest_arrival_us(&self) -> Option<u64> {
        self.entries.front().map(|e| e.arrival_us)
    }

    /// Deadline of the oldest waiting entry.
    pub fn oldest_deadline_us(&self) -> Option<u64> {
        self.entries.front().map(|e| e.deadline_us)
    }

    /// Tightest deadline across *all* waiting entries — what the
    /// deadline-risk launch trigger must watch: with per-request deadlines
    /// a later arrival can be more urgent than the queue front.  O(len),
    /// and len is bounded by the (small) queue capacity.
    pub fn earliest_deadline_us(&self) -> Option<u64> {
        self.entries.iter().map(|e| e.deadline_us).min()
    }

    /// Try to enqueue: sheds (counts, and hands the item back as `Err`)
    /// when at capacity, otherwise pushes and updates the degrade
    /// watermark state.
    pub fn admit(&mut self, item: T, arrival_us: u64, deadline_us: u64)
                 -> Result<Admitted, T> {
        if self.entries.len() >= self.cap {
            self.sheds += 1;
            return Err(item);
        }
        self.entries.push_back(QueueEntry { item, arrival_us, deadline_us });
        self.admitted += 1;
        self.max_depth = self.max_depth.max(self.entries.len());
        let was = self.degraded;
        if !self.degraded && self.entries.len() >= self.degrade_high {
            self.degraded = true;
            self.degrade_transitions += 1;
        }
        Ok(Admitted { degraded: was })
    }

    /// Pop up to `n` oldest entries (a batch) and update the degrade
    /// watermark state after the drain.
    pub fn pop_chunk(&mut self, n: usize) -> Vec<QueueEntry<T>> {
        let take = n.min(self.entries.len());
        let chunk: Vec<QueueEntry<T>> = self.entries.drain(..take).collect();
        if self.degraded && self.entries.len() <= self.degrade_low {
            self.degraded = false;
        }
        chunk
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sheds_at_capacity_and_returns_item() {
        let mut q: DeadlineQueue<usize> = DeadlineQueue::new(2, usize::MAX, 0);
        assert_eq!(q.admit(0, 0, u64::MAX), Ok(Admitted { degraded: false }));
        assert_eq!(q.admit(1, 1, u64::MAX), Ok(Admitted { degraded: false }));
        assert_eq!(q.admit(2, 2, u64::MAX), Err(2), "shed hands the item back");
        assert_eq!(q.len(), 2);
        assert_eq!(q.sheds, 1);
        assert_eq!(q.admitted, 2);
        assert_eq!(q.max_depth, 2);
    }

    #[test]
    fn watermarks_enter_and_leave_degraded_mode() {
        let mut q: DeadlineQueue<usize> = DeadlineQueue::new(8, 3, 1);
        assert!(q.admit(0, 0, u64::MAX).is_ok());
        assert!(q.admit(1, 0, u64::MAX).is_ok());
        assert!(!q.degraded());
        let adm = q.admit(2, 0, u64::MAX); // depth 3 >= high
        assert_eq!(adm, Ok(Admitted { degraded: false }),
                   "the tipping request itself was admitted un-degraded");
        assert!(q.degraded());
        assert_eq!(q.degrade_transitions, 1);
        q.pop_chunk(1); // depth 2 > low: still degraded
        assert!(q.degraded());
        q.pop_chunk(1); // depth 1 <= low: recovered
        assert!(!q.degraded());
    }

    #[test]
    fn pop_chunk_is_fifo_and_clamped() {
        let mut q: DeadlineQueue<usize> = DeadlineQueue::new(8, usize::MAX, 0);
        for i in 0..3usize {
            assert!(q.admit(i, i as u64, u64::MAX).is_ok());
        }
        let chunk = q.pop_chunk(10);
        assert_eq!(chunk.iter().map(|e| e.item).collect::<Vec<_>>(), [0, 1, 2]);
        assert!(q.is_empty());
        assert!(q.oldest_arrival_us().is_none());
    }

    #[test]
    fn oldest_metadata_tracks_front() {
        let mut q: DeadlineQueue<&str> = DeadlineQueue::new(4, usize::MAX, 0);
        assert!(q.admit("a", 10, 100).is_ok());
        assert!(q.admit("b", 20, 50).is_ok());
        assert_eq!(q.oldest_arrival_us(), Some(10));
        assert_eq!(q.oldest_deadline_us(), Some(100));
        q.pop_chunk(1);
        assert_eq!(q.oldest_deadline_us(), Some(50));
    }

    #[test]
    fn earliest_deadline_sees_urgent_entries_behind_the_front() {
        let mut q: DeadlineQueue<&str> = DeadlineQueue::new(4, usize::MAX, 0);
        assert!(q.admit("lazy", 0, u64::MAX).is_ok());
        assert!(q.admit("urgent", 10, 5_000).is_ok());
        // The front has no deadline, but the queue's tightest one is what
        // the deadline-risk trigger must watch.
        assert_eq!(q.oldest_deadline_us(), Some(u64::MAX));
        assert_eq!(q.earliest_deadline_us(), Some(5_000));
        q.pop_chunk(2);
        assert_eq!(q.earliest_deadline_us(), None);
    }
}
