//! Deadline-aware dynamic batch formation.
//!
//! The policy is a pure function over the queue's observable state in
//! microseconds, so the threaded [`Server`](crate::serving::Server) (real
//! clock) and the virtual-time
//! [`EventPipeline`](crate::serving::pipeline::EventPipeline) (simulated
//! clock) share one set of batching semantics.  A batch launches when the
//! first of three triggers fires:
//!
//! 1. **Full** — enough requests wait to fill the largest compiled batch;
//! 2. **MaxWait** — the oldest request has waited the configured maximum;
//! 3. **DeadlineRisk** — waiting any longer would make the most urgent
//!    waiting request (tightest deadline anywhere in the queue) miss it,
//!    given the current service-time estimate.
//!
//! Otherwise the batcher sleeps until the earliest future trigger.

use crate::model::ModelVariant;

/// Why a batch was admitted for execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchReason {
    /// The largest compiled batch size filled up.
    Full,
    /// The oldest request hit the max-wait timer.
    MaxWait,
    /// The most urgent waiting deadline would otherwise be missed.
    DeadlineRisk,
}

impl LaunchReason {
    /// Telemetry counter name for this trigger.
    pub fn counter(&self) -> &'static str {
        match self {
            LaunchReason::Full => "launch_full",
            LaunchReason::MaxWait => "launch_maxwait",
            LaunchReason::DeadlineRisk => "launch_deadline",
        }
    }
}

/// One batching decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchDecision {
    /// Launch a batch now, for the given reason.
    Launch(LaunchReason),
    /// Nothing to launch before this absolute instant (µs); re-evaluate
    /// then, or earlier on a new arrival.
    WaitUntil(u64),
}

/// Decide whether a batch should launch at `now_us`.
///
/// * `queue_len` describes the waiting work, `oldest_arrival_us` the queue
///   front, and `earliest_deadline_us` the tightest deadline over *all*
///   waiting entries (`u64::MAX` means none) — with per-request deadlines
///   a later arrival can be more urgent than the front;
/// * `max_batch` is the largest compiled batch size of the active ladder;
/// * `est_service_us` is the current service-time estimate for the batch
///   that would launch (0 = unknown);
/// * `max_wait_us` / `slack_us` are the policy knobs: the max-wait timer
///   and the safety margin subtracted from deadlines.
#[allow(clippy::too_many_arguments)]
pub fn decide(now_us: u64, queue_len: usize, max_batch: usize,
              oldest_arrival_us: u64, earliest_deadline_us: u64,
              est_service_us: u64, max_wait_us: u64, slack_us: u64)
              -> LaunchDecision {
    debug_assert!(queue_len > 0, "decide() on an empty queue");
    if queue_len >= max_batch {
        return LaunchDecision::Launch(LaunchReason::Full);
    }
    let wait_trigger = oldest_arrival_us.saturating_add(max_wait_us);
    if now_us >= wait_trigger {
        return LaunchDecision::Launch(LaunchReason::MaxWait);
    }
    if earliest_deadline_us != u64::MAX {
        let margin = est_service_us.saturating_add(slack_us);
        if now_us.saturating_add(margin) >= earliest_deadline_us {
            return LaunchDecision::Launch(LaunchReason::DeadlineRisk);
        }
        let deadline_trigger = earliest_deadline_us - margin;
        return LaunchDecision::WaitUntil(
            wait_trigger.min(deadline_trigger).max(now_us + 1),
        );
    }
    LaunchDecision::WaitUntil(wait_trigger.max(now_us + 1))
}

/// Pick the compiled batch size for `len` waiting requests: an exact fit
/// wins; otherwise the smallest size above `len` whose padded-slot fraction
/// stays within `max_pad_ratio` (one amortised execution beats several
/// small ones); otherwise the largest size <= len (batch 1 repeated).
pub fn pick_variant<'v>(variants: &'v [(usize, ModelVariant)], len: usize,
                        max_pad_ratio: f64) -> &'v (usize, ModelVariant) {
    let len = len.max(1);
    if let Some(exact) = variants.iter().find(|(b, _)| *b == len) {
        return exact;
    }
    if let Some(padded) = variants
        .iter()
        .find(|(b, _)| *b > len && (*b - len) as f64 / *b as f64 <= max_pad_ratio)
    {
        return padded;
    }
    variants
        .iter()
        .rev()
        .find(|(b, _)| *b <= len)
        .unwrap_or(&variants[0])
}

/// Last-observed service time (µs) per (ladder, batch size) — the
/// estimate the deadline trigger of [`decide`] works from.  Deliberately a
/// last-value estimator, not an EWMA: on the deterministic simulator the
/// service time of a (variant, conditions) pair is a constant, and on the
/// real path the newest observation already reflects the current thermal /
/// contention state.
#[derive(Debug, Clone, Default)]
pub struct ServiceEstimator {
    entries: std::collections::BTreeMap<(bool, usize), u64>,
}

impl ServiceEstimator {
    /// An empty estimator (every estimate starts at 0 = unknown).
    pub fn new() -> Self {
        ServiceEstimator::default()
    }

    /// Record an observed service time for (`degraded` ladder, `batch`).
    pub fn record(&mut self, degraded: bool, batch: usize, service_us: u64) {
        self.entries.insert((degraded, batch), service_us.max(1));
    }

    /// Current estimate for (`degraded` ladder, `batch`); 0 when unknown.
    pub fn estimate(&self, degraded: bool, batch: usize) -> u64 {
        self.entries.get(&(degraded, batch)).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: u64 = 1000;

    #[test]
    fn full_queue_launches_immediately() {
        let d = decide(0, 8, 8, 0, u64::MAX, 0, 5 * MS, MS);
        assert_eq!(d, LaunchDecision::Launch(LaunchReason::Full));
    }

    #[test]
    fn max_wait_timer_fires() {
        // Oldest arrived at 0, max wait 5 ms: at 5 ms the timer fires.
        let d = decide(5 * MS, 2, 8, 0, u64::MAX, 0, 5 * MS, MS);
        assert_eq!(d, LaunchDecision::Launch(LaunchReason::MaxWait));
        let w = decide(3 * MS, 2, 8, 0, u64::MAX, 0, 5 * MS, MS);
        assert_eq!(w, LaunchDecision::WaitUntil(5 * MS));
    }

    #[test]
    fn deadline_risk_preempts_max_wait() {
        // Deadline at 10 ms, service estimate 6 ms, slack 1 ms: waiting
        // past 3 ms would miss it, even though max-wait allows 20 ms.
        let d = decide(3 * MS, 2, 8, 0, 10 * MS, 6 * MS, 20 * MS, MS);
        assert_eq!(d, LaunchDecision::Launch(LaunchReason::DeadlineRisk));
        let w = decide(MS, 2, 8, 0, 10 * MS, 6 * MS, 20 * MS, MS);
        assert_eq!(w, LaunchDecision::WaitUntil(3 * MS));
    }

    #[test]
    fn wait_until_always_makes_progress() {
        // Degenerate knobs must still advance time by at least 1 µs.
        match decide(7, 1, 8, 7, u64::MAX, 0, 0, 0) {
            LaunchDecision::Launch(LaunchReason::MaxWait) => {}
            other => panic!("expected immediate max-wait launch, got {other:?}"),
        }
    }

    #[test]
    fn estimator_tracks_last_observation() {
        let mut e = ServiceEstimator::new();
        assert_eq!(e.estimate(false, 4), 0);
        e.record(false, 4, 8 * MS);
        e.record(false, 4, 9 * MS);
        assert_eq!(e.estimate(false, 4), 9 * MS);
        assert_eq!(e.estimate(true, 4), 0);
        e.record(true, 4, 0); // clamped to >= 1
        assert_eq!(e.estimate(true, 4), 1);
    }
}
