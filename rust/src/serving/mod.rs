//! Async serving front-end: bounded request queue + dynamic batcher over
//! any execution [`Backend`].
//!
//! The AOT path compiles batched executables for the flagship model
//! (b=1/4/8); the batcher drains the queue, picks the largest compiled batch
//! size that the waiting requests fill (padding the tail by replication when
//! the timeout expires), executes once, and scatters the per-sample outputs
//! back to the callers.  Batching amortises dispatch overhead — the same
//! effect the paper's throughput-oriented use-cases exploit via the
//! recognition-rate parameter.
//!
//! Built on std threads + channels (no tokio on this image); the bounded
//! queue provides backpressure: `submit` blocks when the queue is full,
//! `try_submit` refuses.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::dlacl::{decode_top1, stage_input};
use crate::model::{ModelVariant, Registry};
use crate::runtime::Backend;
use crate::telemetry::Telemetry;

/// One classification request (a camera frame).
pub struct Request {
    pub frame: Vec<f32>,
    pub height: usize,
    pub width: usize,
    reply: mpsc::Sender<Result<Response>>,
    enqueued: Instant,
}

/// The reply to a request.
#[derive(Debug, Clone)]
pub struct Response {
    pub class: usize,
    pub confidence: f32,
    /// Time spent queued before its batch launched (ms).
    pub queue_ms: f64,
    /// End-to-end latency (ms).
    pub total_ms: f64,
    /// Size of the batch this request rode in.
    pub batch: usize,
    /// Name of the model variant that served this request — multi-app
    /// traces attribute latency to a model with it.
    pub variant: String,
}

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// Variants by batch size, ascending (must include batch 1).
    pub variants: Vec<(usize, String)>,
    /// Max time the batcher waits to fill a batch.
    pub max_batch_delay_ms: f64,
    /// Bounded queue capacity (backpressure).
    pub queue_cap: usize,
    pub n_classes: usize,
    /// A flushed tail may round *up* to the next compiled batch size (one
    /// big execution instead of several small ones) when the padded-slot
    /// fraction `(b - len) / b` stays within this bound.
    pub max_pad_ratio: f64,
}

impl ServerConfig {
    /// All compiled batch sizes of `family`/`precision` from the registry.
    pub fn for_family(registry: &Registry, family: &str,
                      precision: crate::model::Precision) -> Result<Self> {
        let mut variants: Vec<(usize, String)> = registry
            .variants()
            .iter()
            .filter(|v| v.family == family && v.precision == precision)
            .map(|v| (v.batch, v.name.clone()))
            .collect();
        variants.sort();
        if variants.is_empty() || variants[0].0 != 1 {
            return Err(anyhow!("no batch-1 variant for {family}"));
        }
        Ok(ServerConfig {
            variants,
            max_batch_delay_ms: 2.0,
            queue_cap: 64,
            n_classes: 10,
            max_pad_ratio: 0.25,
        })
    }
}

/// The serving coordinator.
pub struct Server {
    tx: SyncSender<Request>,
    worker: Option<std::thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    pub telemetry: Arc<Telemetry>,
}

impl Server {
    /// Start the server: loads every batched executable on the backend,
    /// then spawns the batcher thread.
    pub fn start(runtime: Arc<dyn Backend>, registry: &Registry, cfg: ServerConfig)
                 -> Result<Self> {
        let mut loaded: Vec<(usize, ModelVariant)> = Vec::new();
        for (b, name) in &cfg.variants {
            let v = registry
                .get(name)
                .ok_or_else(|| anyhow!("variant `{name}` not in registry"))?
                .clone();
            runtime.load(name, &registry.hlo_path(&v))?;
            loaded.push((*b, v));
        }
        let telemetry = Arc::new(Telemetry::new());
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_cap);
        let worker = {
            let telemetry = Arc::clone(&telemetry);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("oodin-batcher".into())
                .spawn(move || batcher_main(rx, runtime, loaded, cfg, telemetry, stop))?
        };
        Ok(Server { tx, worker: Some(worker), stop, telemetry })
    }

    /// Submit a frame; blocks when the queue is full (backpressure).
    pub fn submit(&self, frame: Vec<f32>, height: usize, width: usize)
                  -> Result<Receiver<Result<Response>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request { frame, height, width, reply, enqueued: Instant::now() })
            .map_err(|_| anyhow!("server stopped"))?;
        Ok(rx)
    }

    /// Non-blocking submit; `None` when the queue is full.
    pub fn try_submit(&self, frame: Vec<f32>, height: usize, width: usize)
                      -> Result<Option<Receiver<Result<Response>>>> {
        let (reply, rx) = mpsc::channel();
        match self.tx.try_send(Request {
            frame, height, width, reply, enqueued: Instant::now(),
        }) {
            Ok(()) => Ok(Some(rx)),
            Err(TrySendError::Full(_)) => Ok(None),
            Err(TrySendError::Disconnected(_)) => Err(anyhow!("server stopped")),
        }
    }

    /// Fraction of executed batch slots that carried replicated padding
    /// rather than a real request: `padded / (padded + real)`.  0.0 before
    /// any batch has run.
    pub fn wasted_compute_ratio(&self) -> f64 {
        let executed = self.telemetry.counter("executed_slots");
        if executed == 0 {
            return 0.0;
        }
        self.telemetry.counter("padded_slots") as f64 / executed as f64
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx.clone()); // original tx dropped in Drop
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Multi-app serving front-end: one [`Server`] (queue + batcher + telemetry)
/// per registered app, all multiplexed over a *single* shared execution
/// backend — the serving seam of the `scheduler` layer.  Each app keeps its
/// own batch-size ladder and backpressure bound; the backend arbitrates the
/// actual executions.
pub struct MultiServer {
    backend: Arc<dyn Backend>,
    apps: BTreeMap<String, Server>,
}

impl MultiServer {
    pub fn new(backend: Arc<dyn Backend>) -> Self {
        MultiServer { backend, apps: BTreeMap::new() }
    }

    pub fn backend(&self) -> Arc<dyn Backend> {
        Arc::clone(&self.backend)
    }

    /// Register an app: starts its dedicated `Server` on the shared backend.
    pub fn register(&mut self, app_id: &str, registry: &Registry,
                    cfg: ServerConfig) -> Result<()> {
        if self.apps.contains_key(app_id) {
            return Err(anyhow!("app `{app_id}` already registered"));
        }
        let srv = Server::start(Arc::clone(&self.backend), registry, cfg)?;
        self.apps.insert(app_id.to_string(), srv);
        Ok(())
    }

    /// The per-app serving handle.
    pub fn app(&self, app_id: &str) -> Option<&Server> {
        self.apps.get(app_id)
    }

    pub fn app_ids(&self) -> impl Iterator<Item = &str> {
        self.apps.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.apps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.apps.is_empty()
    }

    /// Stop every app's batcher; the shared backend outlives the front-end.
    pub fn stop(self) {
        for (_, srv) in self.apps {
            srv.stop();
        }
    }
}

fn batcher_main(rx: Receiver<Request>, runtime: Arc<dyn Backend>,
                variants: Vec<(usize, ModelVariant)>, cfg: ServerConfig,
                telemetry: Arc<Telemetry>, stop: Arc<AtomicBool>) {
    let max_batch = variants.last().map(|(b, _)| *b).unwrap_or(1);
    loop {
        // Block for the first request (with periodic stop checks).
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => r,
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now()
            + Duration::from_micros((cfg.max_batch_delay_ms * 1e3) as u64);
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(_) => break,
            }
        }
        serve_batch(&*runtime, &variants, &cfg, batch, &telemetry);
    }
}

/// Pick the compiled batch size for `len` waiting requests: an exact fit
/// wins; otherwise the smallest size above `len` whose padded-slot fraction
/// stays within `max_pad_ratio` (one amortised execution beats several
/// small ones); otherwise the largest size <= len (batch 1 repeated).
fn pick_variant<'v>(variants: &'v [(usize, ModelVariant)], len: usize,
                    max_pad_ratio: f64) -> &'v (usize, ModelVariant) {
    let len = len.max(1);
    if let Some(exact) = variants.iter().find(|(b, _)| *b == len) {
        return exact;
    }
    if let Some(padded) = variants
        .iter()
        .find(|(b, _)| *b > len && (*b - len) as f64 / *b as f64 <= max_pad_ratio)
    {
        return padded;
    }
    variants
        .iter()
        .rev()
        .find(|(b, _)| *b <= len)
        .unwrap_or(&variants[0])
}

fn serve_batch(runtime: &dyn Backend, variants: &[(usize, ModelVariant)],
               cfg: &ServerConfig, batch: Vec<Request>, telemetry: &Telemetry) {
    let mut remaining = batch;
    while !remaining.is_empty() {
        let (bsz, v) = pick_variant(variants, remaining.len(), cfg.max_pad_ratio);
        let take = (*bsz).min(remaining.len());
        let chunk: Vec<Request> = remaining.drain(..take).collect();

        // Stage: fill [bsz, res, res, 3]; the tail (if chunk < bsz after a
        // timeout flush) replicates the last sample and is discarded.
        let per = v.resolution * v.resolution * 3;
        let mut input = vec![0.0f32; bsz * per];
        for (i, r) in chunk.iter().enumerate() {
            stage_input(&r.frame, r.height, r.width,
                        &mut input[i * per..(i + 1) * per], v.resolution);
        }
        for i in chunk.len()..*bsz {
            let (a, b) = input.split_at_mut(i * per);
            b[..per].copy_from_slice(&a[(chunk.len() - 1) * per..][..per]);
        }

        let t0 = Instant::now();
        let result = runtime.execute(&v.name, input, &v.input_shape);
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        telemetry.record("batch_exec_ms", exec_ms);
        telemetry.add("batched_requests", chunk.len() as u64);
        telemetry.add("executed_slots", *bsz as u64);
        telemetry.add("padded_slots", (*bsz - chunk.len()) as u64);
        telemetry.incr(&format!("batch_size_{bsz}"));

        match result {
            Ok(out) => {
                let stride = out.values.len() / bsz;
                for (i, r) in chunk.into_iter().enumerate() {
                    let (class, confidence) = decode_top1(
                        &out.values[i * stride..(i + 1) * stride], cfg.n_classes);
                    let queue_ms =
                        (t0 - r.enqueued).as_secs_f64() * 1e3;
                    let _ = r.reply.send(Ok(Response {
                        class,
                        confidence,
                        queue_ms,
                        total_ms: r.enqueued.elapsed().as_secs_f64() * 1e3,
                        batch: *bsz,
                        variant: v.name.clone(),
                    }));
                }
            }
            Err(e) => {
                for r in chunk {
                    let _ = r.reply.send(Err(anyhow!("exec failed: {e}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_a71;
    use crate::model::test_fixtures::serving_registry;
    use crate::runtime::SimBackend;
    use crate::sil::camera::class_frame;

    const RES: usize = 16;

    fn backend(reg: &Registry) -> Arc<dyn Backend> {
        Arc::new(SimBackend::new(samsung_a71(), reg.clone()))
    }

    fn config(reg: &Registry) -> ServerConfig {
        ServerConfig::for_family(reg, "cls", crate::model::Precision::Fp32).unwrap()
    }

    #[test]
    fn serves_single_request() {
        let reg = serving_registry(RES);
        let srv = Server::start(backend(&reg), &reg, config(&reg)).unwrap();
        let rx = srv.submit(class_frame(RES, 9), RES, RES).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.class, 9);
        assert!(resp.total_ms >= 0.0);
        srv.stop();
    }

    #[test]
    fn batches_concurrent_requests() {
        let reg = serving_registry(RES);
        let mut cfg = config(&reg);
        cfg.max_batch_delay_ms = 20.0;
        let srv = Server::start(backend(&reg), &reg, cfg).unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|c| srv.submit(class_frame(RES, c), RES, RES).unwrap())
            .collect();
        let resps: Vec<Response> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap())
            .collect();
        // Each response carries its own request's class — no cross-wiring.
        for (c, r) in resps.iter().enumerate() {
            assert_eq!(r.class, c, "response {c} mapped to wrong request");
        }
        // At least one multi-sample batch must have formed.
        assert!(srv.telemetry.counter("batch_size_4") >= 1,
                "batches: {:?}", srv.telemetry.snapshot());
        srv.stop();
    }

    #[test]
    fn try_submit_backpressure() {
        let reg = serving_registry(RES);
        let be: Arc<dyn Backend> =
            Arc::new(SimBackend::new(samsung_a71(), reg.clone()).with_wall_delay_ms(5.0));
        let mut cfg = config(&reg);
        cfg.queue_cap = 1;
        cfg.max_batch_delay_ms = 1.0;
        let srv = Server::start(be, &reg, cfg).unwrap();
        // Saturate: with a 1-deep queue some try_submits must be refused.
        let mut refused = 0;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            match srv.try_submit(class_frame(RES, 1), RES, RES).unwrap() {
                Some(rx) => rxs.push(rx),
                None => refused += 1,
            }
        }
        for rx in rxs {
            let _ = rx.recv();
        }
        assert!(refused > 0, "expected backpressure refusals");
        srv.stop();
    }

    #[test]
    fn pick_variant_exact_pad_up_and_fallback() {
        let reg = serving_registry(RES);
        let v1 = reg.get("cls__fp32__b1").unwrap().clone();
        let v4 = reg.get("cls__fp32__b4").unwrap().clone();
        let vars = vec![(1, v1), (4, v4)];
        assert_eq!(pick_variant(&vars, 1, 0.25).0, 1); // exact
        assert_eq!(pick_variant(&vars, 3, 0.25).0, 4); // pad 1/4 slots
        assert_eq!(pick_variant(&vars, 2, 0.25).0, 1); // 2/4 waste: too much
        assert_eq!(pick_variant(&vars, 4, 0.25).0, 4); // exact
        assert_eq!(pick_variant(&vars, 9, 0.25).0, 4); // largest fitting
        // Pad-up disabled: the old largest-fitting policy throughout.
        assert_eq!(pick_variant(&vars, 3, 0.0).0, 1);
    }

    #[test]
    fn responses_carry_serving_variant() {
        let reg = serving_registry(RES);
        let srv = Server::start(backend(&reg), &reg, config(&reg)).unwrap();
        let rx = srv.submit(class_frame(RES, 3), RES, RES).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.variant, "cls__fp32__b1");
        srv.stop();
    }

    #[test]
    fn multi_server_isolated_apps_shared_backend() {
        let reg = serving_registry(RES);
        let mut multi = MultiServer::new(backend(&reg));
        multi.register("camera", &reg, config(&reg)).unwrap();
        multi.register("ocr", &reg, config(&reg)).unwrap();
        assert!(multi.register("camera", &reg, config(&reg)).is_err());
        assert_eq!(multi.len(), 2);

        let rx_a = multi.app("camera").unwrap()
            .submit(class_frame(RES, 2), RES, RES).unwrap();
        let rx_b = multi.app("ocr").unwrap()
            .submit(class_frame(RES, 7), RES, RES).unwrap();
        assert_eq!(rx_a.recv().unwrap().unwrap().class, 2);
        assert_eq!(rx_b.recv().unwrap().unwrap().class, 7);
        // Per-app telemetry stays isolated.
        assert_eq!(multi.app("camera").unwrap().telemetry.counter("batched_requests"), 1);
        assert_eq!(multi.app("ocr").unwrap().telemetry.counter("batched_requests"), 1);
        assert!(multi.app("missing").is_none());
        multi.stop();
    }
}
