//! Async serving front-end: an event-driven pipeline — bounded
//! [`queue`] → deadline-aware [`batch`] formation → per-engine worker
//! lanes — over any execution [`Backend`].
//!
//! The AOT path compiles batched executables for a family (b=1/4/8); the
//! pipeline admits requests into a bounded deadline queue (shedding, with
//! counts, once it is full), forms batches when the largest compiled size
//! fills, the oldest request's deadline approaches, or the max-wait timer
//! fires, and executes them on worker lanes that each carry an optional
//! engine hint over the *shared* backend.  Under queue pressure the
//! pipeline *degrades* — it serves from a cheaper (lower-precision) batch
//! ladder until the backlog drains, the serving-side analogue of the
//! scheduler's degrade-or-reject admission control.
//!
//! Two drivers share these mechanics:
//!
//! * [`Server`] — real threads and wall-clock time (std threads +
//!   channels; no tokio on this image).  `submit` blocks when the queue is
//!   full (backpressure), `try_submit` refuses and counts the shed.
//! * [`pipeline::EventPipeline`] — the same queue/policy/lanes advanced on
//!   a deterministic integer-µs virtual clock, used by
//!   `experiments::loadgen` and the `serve-bench` golden snapshot.
//!
//! Telemetry: `queue_depth` samples, `shed_requests`, `deadline_misses`,
//! `degraded_requests`, per-trigger `launch_*` counters, and the PR 2
//! padded-slot accounting (`executed_slots` / `padded_slots` /
//! [`Server::wasted_compute_ratio`]).

pub mod batch;
pub mod pipeline;
pub mod queue;

pub use batch::{decide, pick_variant, LaunchDecision, LaunchReason,
                ServiceEstimator};
pub use pipeline::{Completion, EventPipeline, TraceReport};
pub use queue::{Admitted, DeadlineQueue, QueueEntry};

use std::collections::BTreeMap;
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::dlacl::{decode_top1, stage_input};
use crate::manager::Conditions;
use crate::model::{ModelVariant, Precision, Registry};
use crate::runtime::{Backend, ExecHint};
use crate::scheduler::{Admission, Scheduler, WorkloadDescriptor};
use crate::telemetry::Telemetry;

/// One classification request (a camera frame) waiting in the queue.
pub struct Request {
    /// RGB frame data (HWC, f32).
    pub frame: Vec<f32>,
    /// Frame height in pixels.
    pub height: usize,
    /// Frame width in pixels.
    pub width: usize,
    reply: mpsc::Sender<Result<Response>>,
    enqueued: Instant,
}

/// The reply to a request.
#[derive(Debug, Clone)]
pub struct Response {
    /// Predicted class (top-1).
    pub class: usize,
    /// Top-1 logit score.
    pub confidence: f32,
    /// Time spent queued before its batch launched (ms).
    pub queue_ms: f64,
    /// End-to-end latency (ms).
    pub total_ms: f64,
    /// Size of the batch this request rode in.
    pub batch: usize,
    /// Name of the model variant that served this request — multi-app
    /// traces attribute latency to a model with it.
    pub variant: String,
    /// True when the request completed after its deadline.
    pub missed_deadline: bool,
    /// True when served from the degraded (cheaper) ladder under queue
    /// pressure.
    pub degraded: bool,
}

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    /// Primary ladder: variants by batch size, ascending (must include
    /// batch 1).
    pub variants: Vec<(usize, String)>,
    /// Max time the batcher waits to fill a batch.
    pub max_batch_delay_ms: f64,
    /// Bounded queue capacity (backpressure).
    pub queue_cap: usize,
    /// Classes decoded from the classification head.
    pub n_classes: usize,
    /// A flushed tail may round *up* to the next compiled batch size (one
    /// big execution instead of several small ones) when the padded-slot
    /// fraction `(b - len) / b` stays within this bound.
    pub max_pad_ratio: f64,
    /// Default per-request completion deadline (ms; `INFINITY` = none).
    pub deadline_ms: f64,
    /// Safety margin subtracted from deadlines when predicting misses.
    pub deadline_slack_ms: f64,
    /// Degraded (cheaper) ladder served under queue pressure; empty
    /// disables degrade mode.
    pub degraded_variants: Vec<(usize, String)>,
    /// Queue depth at which degrade mode engages.
    pub degrade_high: usize,
    /// Queue depth at which degrade mode disengages.
    pub degrade_low: usize,
    /// Worker lanes; each optionally pins an engine/threads/governor on
    /// backends that model heterogeneous engines.
    pub lanes: Vec<Option<ExecHint>>,
}

impl ServerConfig {
    /// All compiled batch sizes of `family`/`precision` from the registry,
    /// ascending — empty when the family has no such variants.
    pub fn ladder(registry: &Registry, family: &str, precision: Precision)
                  -> Vec<(usize, String)> {
        let mut variants: Vec<(usize, String)> = registry
            .variants()
            .iter()
            .filter(|v| v.family == family && v.precision == precision)
            .map(|v| (v.batch, v.name.clone()))
            .collect();
        variants.sort();
        variants
    }

    /// Serving defaults over the compiled batch ladder of
    /// `family`/`precision` (which must include batch 1).
    pub fn for_family(registry: &Registry, family: &str,
                      precision: Precision) -> Result<Self> {
        let variants = Self::ladder(registry, family, precision);
        if variants.is_empty() || variants[0].0 != 1 {
            return Err(anyhow!("no batch-1 variant for {family}"));
        }
        Ok(ServerConfig {
            variants,
            max_batch_delay_ms: 2.0,
            queue_cap: 64,
            n_classes: 10,
            max_pad_ratio: 0.25,
            deadline_ms: f64::INFINITY,
            deadline_slack_ms: 0.5,
            degraded_variants: Vec::new(),
            degrade_high: usize::MAX,
            degrade_low: 0,
            lanes: vec![None],
        })
    }

    /// Enable degrade mode: serve `precision` (typically INT8) once the
    /// queue reaches `high` waiting requests, back to the primary ladder
    /// at `low`.  No-op when the family lacks that ladder.
    pub fn with_degraded(mut self, registry: &Registry, family: &str,
                         precision: Precision, high: usize, low: usize)
                         -> Self {
        let ladder = Self::ladder(registry, family, precision);
        if !ladder.is_empty() {
            self.degraded_variants = ladder;
            self.degrade_high = high;
            self.degrade_low = low;
        }
        self
    }
}

/// Shared worker/submitter state behind the queue mutex.
struct Inner {
    queue: DeadlineQueue<Request>,
    est: ServiceEstimator,
    stopping: bool,
}

struct Shared {
    inner: Mutex<Inner>,
    /// Signalled on new work and on stop.
    work: Condvar,
    /// Signalled when the queue drains (unblocks backpressured `submit`).
    space: Condvar,
}

/// The threaded serving coordinator: bounded queue + deadline-aware
/// batcher + per-lane worker threads over one shared backend.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    t0: Instant,
    deadline_ms: f64,
    /// Metrics sink (counters + latency samples) for this app's pipeline.
    pub telemetry: Arc<Telemetry>,
}

/// Resolve a (batch, variant-name) ladder against the registry and load
/// every executable on the backend — shared by the threaded [`Server`] and
/// the virtual-time [`EventPipeline`] so the two drivers cannot diverge.
pub(crate) fn resolve_ladder(runtime: &dyn Backend, registry: &Registry,
                             names: &[(usize, String)])
                             -> Result<Vec<(usize, ModelVariant)>> {
    let mut out = Vec::new();
    for (b, name) in names {
        let v = registry
            .get(name)
            .ok_or_else(|| anyhow!("variant `{name}` not in registry"))?
            .clone();
        runtime.load(name, &registry.hlo_path(&v))?;
        out.push((*b, v));
    }
    Ok(out)
}

/// Validate the resolved ladders + lanes a pipeline driver was given.
pub(crate) fn check_pipeline_config(primary: &[(usize, ModelVariant)],
                                    lanes: &[Option<ExecHint>])
                                    -> Result<()> {
    if primary.is_empty() {
        return Err(anyhow!("serving needs at least one primary variant"));
    }
    if lanes.is_empty() {
        return Err(anyhow!("serving needs at least one worker lane"));
    }
    Ok(())
}

impl Server {
    /// Start the server: loads both ladders' executables on the backend,
    /// then spawns one worker thread per configured lane.
    pub fn start(runtime: Arc<dyn Backend>, registry: &Registry,
                 cfg: ServerConfig) -> Result<Self> {
        let primary = resolve_ladder(&*runtime, registry, &cfg.variants)?;
        let degraded =
            resolve_ladder(&*runtime, registry, &cfg.degraded_variants)?;
        check_pipeline_config(&primary, &cfg.lanes)?;
        let telemetry = Arc::new(Telemetry::new());
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                queue: DeadlineQueue::new(cfg.queue_cap, cfg.degrade_high,
                                          cfg.degrade_low),
                est: ServiceEstimator::new(),
                stopping: false,
            }),
            work: Condvar::new(),
            space: Condvar::new(),
        });
        let t0 = Instant::now();
        let mut workers = Vec::new();
        for (lane, hint) in cfg.lanes.iter().enumerate() {
            let shared = Arc::clone(&shared);
            let runtime = Arc::clone(&runtime);
            let telemetry = Arc::clone(&telemetry);
            let primary = primary.clone();
            let degraded = degraded.clone();
            let cfg = cfg.clone();
            let hint = *hint;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("oodin-batcher-{lane}"))
                    .spawn(move || {
                        worker_main(shared, runtime, primary, degraded, cfg,
                                    hint, telemetry, t0)
                    })?,
            );
        }
        Ok(Server {
            shared,
            workers,
            t0,
            deadline_ms: cfg.deadline_ms,
            telemetry,
        })
    }

    fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    fn deadline_us(&self, now_us: u64, deadline_ms: f64) -> u64 {
        if deadline_ms.is_finite() {
            now_us.saturating_add((deadline_ms * 1e3).round() as u64)
        } else {
            u64::MAX
        }
    }

    /// Submit a frame with the config's default deadline; blocks when the
    /// queue is full (backpressure).
    pub fn submit(&self, frame: Vec<f32>, height: usize, width: usize)
                  -> Result<Receiver<Result<Response>>> {
        self.submit_with_deadline(frame, height, width, self.deadline_ms)
    }

    /// Submit a frame that should complete within `deadline_ms`
    /// (`INFINITY` = no deadline); blocks when the queue is full.
    pub fn submit_with_deadline(&self, frame: Vec<f32>, height: usize,
                                width: usize, deadline_ms: f64)
                                -> Result<Receiver<Result<Response>>> {
        let (reply, rx) = mpsc::channel();
        let mut job = Request {
            frame, height, width, reply, enqueued: Instant::now(),
        };
        let mut g = self.shared.inner.lock().unwrap();
        loop {
            if g.stopping {
                return Err(anyhow!("server stopped"));
            }
            let now = self.now_us();
            match g.queue.admit(job, now, self.deadline_us(now, deadline_ms)) {
                Ok(_) => {
                    self.telemetry.record("queue_depth", g.queue.len() as f64);
                    self.shared.work.notify_all();
                    return Ok(rx);
                }
                Err(returned) => {
                    job = returned;
                    g = self.shared.space.wait(g).unwrap();
                }
            }
        }
    }

    /// Non-blocking submit; `None` when the queue is full (the shed is
    /// counted in `shed_requests`).
    pub fn try_submit(&self, frame: Vec<f32>, height: usize, width: usize)
                      -> Result<Option<Receiver<Result<Response>>>> {
        let (reply, rx) = mpsc::channel();
        let job = Request {
            frame, height, width, reply, enqueued: Instant::now(),
        };
        let mut g = self.shared.inner.lock().unwrap();
        if g.stopping {
            return Err(anyhow!("server stopped"));
        }
        let now = self.now_us();
        match g.queue.admit(job, now, self.deadline_us(now, self.deadline_ms)) {
            Ok(_) => {
                self.telemetry.record("queue_depth", g.queue.len() as f64);
                self.shared.work.notify_all();
                Ok(Some(rx))
            }
            Err(_) => {
                self.telemetry.incr("shed_requests");
                Ok(None)
            }
        }
    }

    /// Fraction of executed batch slots that carried replicated padding
    /// rather than a real request: `padded / (padded + real)`.  0.0 before
    /// any batch has run.
    pub fn wasted_compute_ratio(&self) -> f64 {
        let executed = self.telemetry.counter("executed_slots");
        if executed == 0 {
            return 0.0;
        }
        self.telemetry.counter("padded_slots") as f64 / executed as f64
    }

    /// Stop accepting work, drain the queue, and join the workers.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        {
            let mut g = self.shared.inner.lock().unwrap();
            g.stopping = true;
        }
        self.shared.work.notify_all();
        self.shared.space.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// One worker lane: waits for queued work, runs the deadline-aware batch
/// policy, executes the formed batch on this lane's engine hint, and
/// scatters the replies.
#[allow(clippy::too_many_arguments)]
fn worker_main(shared: Arc<Shared>, runtime: Arc<dyn Backend>,
               primary: Vec<(usize, ModelVariant)>,
               degraded: Vec<(usize, ModelVariant)>, cfg: ServerConfig,
               hint: Option<ExecHint>, telemetry: Arc<Telemetry>,
               t0: Instant) {
    let max_wait_us = (cfg.max_batch_delay_ms * 1e3).round() as u64;
    let slack_us = (cfg.deadline_slack_ms * 1e3).round() as u64;
    let mut g = shared.inner.lock().unwrap();
    loop {
        if g.queue.is_empty() {
            if g.stopping {
                return;
            }
            let (guard, _) = shared
                .work
                .wait_timeout(g, Duration::from_millis(50))
                .unwrap();
            g = guard;
            continue;
        }
        let now = t0.elapsed().as_micros() as u64;
        let use_degraded = g.queue.degraded() && !degraded.is_empty();
        let ladder = if use_degraded { &degraded } else { &primary };
        let max_batch = ladder.last().map(|(b, _)| *b).unwrap_or(1);
        let (bsz, v) = {
            let picked = pick_variant(ladder, g.queue.len(), cfg.max_pad_ratio);
            (picked.0, picked.1.clone())
        };
        let est = g.est.estimate(use_degraded, bsz);
        match decide(now, g.queue.len(), max_batch,
                     g.queue.oldest_arrival_us().expect("non-empty queue"),
                     g.queue.earliest_deadline_us().expect("non-empty queue"),
                     est, max_wait_us, slack_us) {
            LaunchDecision::WaitUntil(t) => {
                let wait = Duration::from_micros(t.saturating_sub(now).max(1));
                let (guard, _) = shared.work.wait_timeout(g, wait).unwrap();
                g = guard;
            }
            LaunchDecision::Launch(reason) => {
                telemetry.incr(reason.counter());
                let n = bsz.min(g.queue.len());
                let chunk = g.queue.pop_chunk(n);
                drop(g);
                shared.space.notify_all();
                let svc_us = serve_chunk(&*runtime, &v, bsz, chunk, now,
                                         use_degraded, hint.as_ref(),
                                         cfg.n_classes, &telemetry, t0);
                g = shared.inner.lock().unwrap();
                if let Some(svc) = svc_us {
                    g.est.record(use_degraded, bsz, svc);
                }
            }
        }
    }
}

/// Stage one formed batch, execute it, and scatter per-sample replies.
/// Returns the observed service time (µs) on success.
#[allow(clippy::too_many_arguments)]
fn serve_chunk(runtime: &dyn Backend, v: &ModelVariant, bsz: usize,
               chunk: Vec<QueueEntry<Request>>, launched_us: u64,
               degraded: bool, hint: Option<&ExecHint>, n_classes: usize,
               telemetry: &Telemetry, t0: Instant) -> Option<u64> {
    // Stage: fill [bsz, res, res, 3]; the tail (if chunk < bsz after a
    // timeout flush) replicates the last sample and is discarded.
    let per = v.resolution * v.resolution * 3;
    let mut input = vec![0.0f32; bsz * per];
    for (i, e) in chunk.iter().enumerate() {
        stage_input(&e.item.frame, e.item.height, e.item.width,
                    &mut input[i * per..(i + 1) * per], v.resolution);
    }
    for i in chunk.len()..bsz {
        let (a, b) = input.split_at_mut(i * per);
        b[..per].copy_from_slice(&a[(chunk.len() - 1) * per..][..per]);
    }

    let wall0 = Instant::now();
    let result = runtime.execute_hinted(&v.name, input, &v.input_shape, hint);
    let exec_ms = wall0.elapsed().as_secs_f64() * 1e3;
    telemetry.record("batch_exec_ms", exec_ms);
    telemetry.add("batched_requests", chunk.len() as u64);
    telemetry.add("executed_slots", bsz as u64);
    telemetry.add("padded_slots", (bsz - chunk.len()) as u64);
    telemetry.incr(&format!("batch_size_{bsz}"));
    if degraded {
        telemetry.add("degraded_requests", chunk.len() as u64);
    }

    match result {
        Ok(out) => {
            let svc_us = (out.host_ms * 1e3).round().max(1.0) as u64;
            let done_us = t0.elapsed().as_micros() as u64;
            let stride = out.values.len() / bsz;
            for (i, e) in chunk.into_iter().enumerate() {
                let (class, confidence) = decode_top1(
                    &out.values[i * stride..(i + 1) * stride], n_classes);
                let missed = done_us > e.deadline_us;
                if missed {
                    telemetry.incr("deadline_misses");
                }
                let _ = e.item.reply.send(Ok(Response {
                    class,
                    confidence,
                    queue_ms: launched_us.saturating_sub(e.arrival_us) as f64
                        / 1e3,
                    total_ms: e.item.enqueued.elapsed().as_secs_f64() * 1e3,
                    batch: bsz,
                    variant: v.name.clone(),
                    missed_deadline: missed,
                    degraded,
                }));
            }
            Some(svc_us)
        }
        Err(err) => {
            for e in chunk {
                let _ = e.item.reply.send(Err(anyhow!("exec failed: {err}")));
            }
            None
        }
    }
}

/// Multi-app serving front-end: one [`Server`] (queue + batcher + telemetry)
/// per registered app, all multiplexed over a *single* shared execution
/// backend — the serving seam of the `scheduler` layer.  Each app keeps its
/// own batch-size ladder and backpressure bound; the backend arbitrates the
/// actual executions.
pub struct MultiServer {
    backend: Arc<dyn Backend>,
    apps: BTreeMap<String, Server>,
}

impl MultiServer {
    /// An empty front-end over one shared backend.
    pub fn new(backend: Arc<dyn Backend>) -> Self {
        MultiServer { backend, apps: BTreeMap::new() }
    }

    /// The shared execution backend.
    pub fn backend(&self) -> Arc<dyn Backend> {
        Arc::clone(&self.backend)
    }

    /// Register an app: starts its dedicated `Server` on the shared backend.
    pub fn register(&mut self, app_id: &str, registry: &Registry,
                    cfg: ServerConfig) -> Result<()> {
        if self.apps.contains_key(app_id) {
            return Err(anyhow!("app `{app_id}` already registered"));
        }
        let srv = Server::start(Arc::clone(&self.backend), registry, cfg)?;
        self.apps.insert(app_id.to_string(), srv);
        Ok(())
    }

    /// The serving configuration a jointly-chosen design implies: the
    /// design family's batch ladder, an INT8 degraded ladder (when one
    /// exists) for overload brownout, the app's SLO as the request
    /// deadline, and one worker lane pinned to the design's
    /// engine/threads/governor.  Shared by admission
    /// ([`MultiServer::register_admitted`]) and re-adaptation
    /// ([`MultiServer::readapt`]).
    fn config_for_design(registry: &Registry, design: &crate::optimizer::Design,
                         slo_latency_ms: f64) -> Result<ServerConfig> {
        let v = registry.get(&design.variant).ok_or_else(|| {
            anyhow!("admitted variant `{}` not in registry", design.variant)
        })?;
        let mut cfg = ServerConfig::for_family(registry, &v.family, v.precision)?;
        if v.precision != Precision::Int8 {
            let high = (cfg.queue_cap * 3) / 4;
            let low = cfg.queue_cap / 4;
            cfg = cfg.with_degraded(registry, &v.family, Precision::Int8,
                                    high, low);
        }
        cfg.deadline_ms = slo_latency_ms;
        cfg.lanes = vec![Some(ExecHint {
            engine: design.hw.engine,
            threads: design.hw.threads,
            governor: design.hw.governor,
        })];
        Ok(cfg)
    }

    /// Register an app through the multi-app scheduler's admission control
    /// (degrade-or-reject): on admission, the app's server is configured
    /// from the jointly-chosen design — its family/precision ladder, a
    /// worker lane pinned to the design's engine/threads/governor, and an
    /// INT8 degraded ladder (when one exists) for overload brownout.
    /// Rejected apps get no server.
    pub fn register_admitted(&mut self, scheduler: &mut Scheduler,
                             registry: &Registry, desc: WorkloadDescriptor,
                             now_ms: f64, conds: &Conditions)
                             -> Result<Admission> {
        let app_id = desc.app_id.clone();
        let slo_latency_ms = desc.slo_latency_ms;
        let adm = scheduler.register(desc, now_ms, conds)?;
        if let Admission::Admitted { design, .. } = &adm {
            let cfg = Self::config_for_design(registry, design, slo_latency_ms)?;
            self.register(&app_id, registry, cfg)?;
        }
        Ok(adm)
    }

    /// Serving-side joint re-adaptation: run the scheduler's coordinated
    /// re-optimisation (an O(frontier) walk over the cached per-app Pareto
    /// frontiers — see [`crate::designspace`]) and, for every app whose
    /// design switched, restart its `Server` with the new design's ladder
    /// and engine lane.  In-flight requests of a restarted app drain on
    /// the old server before it stops.  Every switched app is attempted —
    /// a failed restart leaves that app serving on its previous
    /// configuration and is reported in the returned error (naming the
    /// apps) only after the remaining switches have been applied, so a
    /// single failure cannot silently desynchronise the rest of the
    /// fleet.  Caveat: the scheduler has already committed the switch, so
    /// a named-failed app serves on its old lane while the arbiter
    /// accounts for the new one until the caller re-registers it or a
    /// later re-adaptation moves it again — the error exists precisely so
    /// the caller can repair that.  Returns the coordinated switches.
    pub fn readapt(&mut self, scheduler: &mut Scheduler, registry: &Registry,
                   now_ms: f64, conds: &Conditions)
                   -> Result<Vec<(String, crate::manager::Switch)>> {
        let issued = scheduler.observe(now_ms, conds);
        let mut failures: Vec<String> = Vec::new();
        for (app_id, sw) in &issued {
            if !self.apps.contains_key(app_id) {
                continue; // scheduler tenant without a serving front-end
            }
            let slo = scheduler
                .descriptors()
                .iter()
                .find(|d| &d.app_id == app_id)
                .map(|d| d.slo_latency_ms)
                .unwrap_or(f64::INFINITY);
            // Build and start the replacement *before* tearing the old
            // server down: a failure here leaves the app serving on its
            // previous configuration instead of dropping it.
            let started = Self::config_for_design(registry, &sw.to, slo)
                .and_then(|cfg| {
                    Server::start(Arc::clone(&self.backend), registry, cfg)
                });
            match started {
                Ok(srv) => {
                    if let Some(old) = self.apps.remove(app_id) {
                        old.stop();
                    }
                    self.apps.insert(app_id.clone(), srv);
                }
                Err(e) => failures.push(format!("{app_id}: {e:#}")),
            }
        }
        if !failures.is_empty() {
            return Err(anyhow!(
                "readapt: {} of {} switched servers failed to restart \
                 (still serving their previous designs): {}",
                failures.len(), issued.len(), failures.join("; ")
            ));
        }
        Ok(issued)
    }

    /// The per-app serving handle.
    pub fn app(&self, app_id: &str) -> Option<&Server> {
        self.apps.get(app_id)
    }

    /// Registered app ids, sorted.
    pub fn app_ids(&self) -> impl Iterator<Item = &str> {
        self.apps.keys().map(|s| s.as_str())
    }

    /// Number of registered apps.
    pub fn len(&self) -> usize {
        self.apps.len()
    }

    /// True when no app is registered.
    pub fn is_empty(&self) -> bool {
        self.apps.is_empty()
    }

    /// Stop every app's batcher; the shared backend outlives the front-end.
    pub fn stop(self) {
        for (_, srv) in self.apps {
            srv.stop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_a71;
    use crate::measurements::Measurer;
    use crate::model::test_fixtures::{fake_registry, serving_registry};
    use crate::optimizer::Objective;
    use crate::runtime::SimBackend;
    use crate::sil::camera::class_frame;
    use crate::util::stats::Percentile;

    const RES: usize = 16;

    fn backend(reg: &Registry) -> Arc<dyn Backend> {
        Arc::new(SimBackend::new(samsung_a71(), reg.clone()))
    }

    fn config(reg: &Registry) -> ServerConfig {
        ServerConfig::for_family(reg, "cls", crate::model::Precision::Fp32).unwrap()
    }

    #[test]
    fn serves_single_request() {
        let reg = serving_registry(RES);
        let srv = Server::start(backend(&reg), &reg, config(&reg)).unwrap();
        let rx = srv.submit(class_frame(RES, 9), RES, RES).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.class, 9);
        assert!(resp.total_ms >= 0.0);
        assert!(!resp.missed_deadline, "no deadline configured by default");
        assert!(!resp.degraded);
        srv.stop();
    }

    #[test]
    fn batches_concurrent_requests() {
        let reg = serving_registry(RES);
        let mut cfg = config(&reg);
        cfg.max_batch_delay_ms = 20.0;
        let srv = Server::start(backend(&reg), &reg, cfg).unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|c| srv.submit(class_frame(RES, c), RES, RES).unwrap())
            .collect();
        let resps: Vec<Response> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap())
            .collect();
        // Each response carries its own request's class — no cross-wiring.
        for (c, r) in resps.iter().enumerate() {
            assert_eq!(r.class, c, "response {c} mapped to wrong request");
        }
        // At least one multi-sample batch must have formed.
        assert!(srv.telemetry.counter("batch_size_4") >= 1,
                "batches: {:?}", srv.telemetry.snapshot());
        srv.stop();
    }

    #[test]
    fn try_submit_backpressure() {
        let reg = serving_registry(RES);
        let be: Arc<dyn Backend> =
            Arc::new(SimBackend::new(samsung_a71(), reg.clone()).with_wall_delay_ms(5.0));
        let mut cfg = config(&reg);
        cfg.queue_cap = 1;
        cfg.max_batch_delay_ms = 1.0;
        let srv = Server::start(be, &reg, cfg).unwrap();
        // Saturate: with a 1-deep queue some try_submits must be refused.
        let mut refused = 0;
        let mut rxs = Vec::new();
        for _ in 0..64 {
            match srv.try_submit(class_frame(RES, 1), RES, RES).unwrap() {
                Some(rx) => rxs.push(rx),
                None => refused += 1,
            }
        }
        for rx in rxs {
            let _ = rx.recv();
        }
        assert!(refused > 0, "expected backpressure refusals");
        // Refusals are counted, not silent.
        assert_eq!(srv.telemetry.counter("shed_requests"), refused);
        srv.stop();
    }

    #[test]
    fn pick_variant_exact_pad_up_and_fallback() {
        let reg = serving_registry(RES);
        let v1 = reg.get("cls__fp32__b1").unwrap().clone();
        let v4 = reg.get("cls__fp32__b4").unwrap().clone();
        let vars = vec![(1, v1), (4, v4)];
        assert_eq!(pick_variant(&vars, 1, 0.25).0, 1); // exact
        assert_eq!(pick_variant(&vars, 3, 0.25).0, 4); // pad 1/4 slots
        assert_eq!(pick_variant(&vars, 2, 0.25).0, 1); // 2/4 waste: too much
        assert_eq!(pick_variant(&vars, 4, 0.25).0, 4); // exact
        assert_eq!(pick_variant(&vars, 9, 0.25).0, 4); // largest fitting
        // Pad-up disabled: the old largest-fitting policy throughout.
        assert_eq!(pick_variant(&vars, 3, 0.0).0, 1);
    }

    #[test]
    fn responses_carry_serving_variant() {
        let reg = serving_registry(RES);
        let srv = Server::start(backend(&reg), &reg, config(&reg)).unwrap();
        let rx = srv.submit(class_frame(RES, 3), RES, RES).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.variant, "cls__fp32__b1");
        srv.stop();
    }

    #[test]
    fn generous_deadline_is_met_and_recorded() {
        let reg = serving_registry(RES);
        let srv = Server::start(backend(&reg), &reg, config(&reg)).unwrap();
        let rx = srv
            .submit_with_deadline(class_frame(RES, 5), RES, RES, 10_000.0)
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.class, 5);
        assert!(!resp.missed_deadline, "10 s deadline on an idle server");
        assert_eq!(srv.telemetry.counter("deadline_misses"), 0);
        srv.stop();
    }

    #[test]
    fn multi_server_isolated_apps_shared_backend() {
        let reg = serving_registry(RES);
        let mut multi = MultiServer::new(backend(&reg));
        multi.register("camera", &reg, config(&reg)).unwrap();
        multi.register("ocr", &reg, config(&reg)).unwrap();
        assert!(multi.register("camera", &reg, config(&reg)).is_err());
        assert_eq!(multi.len(), 2);

        let rx_a = multi.app("camera").unwrap()
            .submit(class_frame(RES, 2), RES, RES).unwrap();
        let rx_b = multi.app("ocr").unwrap()
            .submit(class_frame(RES, 7), RES, RES).unwrap();
        assert_eq!(rx_a.recv().unwrap().unwrap().class, 2);
        assert_eq!(rx_b.recv().unwrap().unwrap().class, 7);
        // Per-app telemetry stays isolated.
        assert_eq!(multi.app("camera").unwrap().telemetry.counter("batched_requests"), 1);
        assert_eq!(multi.app("ocr").unwrap().telemetry.counter("batched_requests"), 1);
        assert!(multi.app("missing").is_none());
        multi.stop();
    }

    #[test]
    fn register_admitted_wires_scheduler_admission_to_serving() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        let mut sched = Scheduler::new(Arc::new(dev.clone()),
                                       Arc::new(reg.clone()), Arc::new(lut));
        let mut multi = MultiServer::new(backend(&reg));
        let idle = Conditions::idle();
        let desc = WorkloadDescriptor {
            app_id: "cam".into(),
            family: "mobilenet_v2_100".into(),
            arrival_fps: 30.0,
            objective: Objective::MinLatency {
                stat: Percentile::Avg,
                epsilon: 0.05,
            },
            slo_latency_ms: 1e6,
        };
        let adm = multi
            .register_admitted(&mut sched, &reg, desc, 0.0, &idle)
            .unwrap();
        assert!(matches!(adm, Admission::Admitted { .. }));
        assert_eq!(multi.len(), 1);
        // The admitted app serves through its scheduler-chosen design.
        let v = reg.get("mobilenet_v2_100__fp32__b1").unwrap();
        let rx = multi.app("cam").unwrap()
            .submit(class_frame(v.resolution, 3), v.resolution, v.resolution)
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.variant.starts_with("mobilenet_v2_100"),
                "served by the admitted design's family: {}", resp.variant);

        // A workload no design can host is rejected: no server appears.
        let ghost = WorkloadDescriptor {
            app_id: "ghost".into(),
            family: "no_such_family".into(),
            arrival_fps: 30.0,
            objective: Objective::MinLatency {
                stat: Percentile::Avg,
                epsilon: 0.05,
            },
            slo_latency_ms: 1e6,
        };
        let adm = multi
            .register_admitted(&mut sched, &reg, ghost, 0.0, &idle)
            .unwrap();
        assert!(matches!(adm, Admission::Rejected { .. }));
        assert_eq!(multi.len(), 1);
        multi.stop();
    }

    #[test]
    fn readapt_restarts_switched_servers_from_the_frontier() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = Measurer::new(&dev, &reg).with_runs(30, 2).measure_all().unwrap();
        let mut sched = Scheduler::new(Arc::new(dev.clone()),
                                       Arc::new(reg.clone()), Arc::new(lut));
        let mut multi = MultiServer::new(backend(&reg));
        let idle = Conditions::idle();
        let desc = WorkloadDescriptor {
            app_id: "cam".into(),
            family: "mobilenet_v2_100".into(),
            arrival_fps: 30.0,
            objective: Objective::MinLatency {
                stat: Percentile::Avg,
                epsilon: 0.05,
            },
            slo_latency_ms: 1e6,
        };
        multi.register_admitted(&mut sched, &reg, desc, 0.0, &idle).unwrap();
        let e0 = sched.design_of("cam").unwrap().hw.engine;

        // Quiet conditions: no switch, server untouched.
        let issued = multi.readapt(&mut sched, &reg, 5000.0, &idle).unwrap();
        assert!(issued.is_empty());

        // Heavy load on the app's engine: the coordinated re-adaptation
        // migrates it and the serving front-end restarts on the new lane.
        let mut loaded = Conditions::idle();
        loaded.loads.insert(e0, 3.0);
        let issued = multi.readapt(&mut sched, &reg, 10_000.0, &loaded).unwrap();
        assert_eq!(issued.len(), 1, "expected one coordinated switch");
        assert_ne!(issued[0].1.to.hw.engine, e0);
        assert_eq!(multi.len(), 1, "restarted in place, not duplicated");

        // The restarted server still serves its app.
        let v = reg.get("mobilenet_v2_100__fp32__b1").unwrap();
        let rx = multi.app("cam").unwrap()
            .submit(class_frame(v.resolution, 4), v.resolution, v.resolution)
            .unwrap();
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.variant.starts_with("mobilenet_v2_100"), "{}", resp.variant);
        multi.stop();
    }
}
