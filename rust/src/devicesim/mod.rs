//! The virtual device: per-engine thermal state + injectable external load
//! on the shared timeline.
//!
//! This is the substrate that stands in for the physical phones (DESIGN.md
//! §Substitutions).  Every inference the Application runs is accounted here:
//! the perf model produces the device latency under the *current* governor /
//! thermal / load conditions, the engine's thermal model integrates the
//! work, and the resulting conditions are what MDCL middleware c reports to
//! the Runtime Manager.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::device::{DeviceProfile, EngineKind};
use crate::dvfs::{Governor, ThermalModel};
use crate::manager::Conditions;
use crate::model::ModelVariant;
use crate::perf::{self, ExecConditions};
use crate::util::clock::Clock;
use crate::util::rng::Rng;

/// One simulated inference's outcome.
#[derive(Debug, Clone, Copy)]
pub struct SimExec {
    /// Device latency under current conditions (ms).
    pub latency_ms: f64,
    /// Engine temperature after the run (deg C).
    pub temp_c: f64,
    /// Thermal frequency scale in effect during the run.
    pub thermal_scale: f64,
}

/// The simulated device.
pub struct DeviceSim {
    /// The resource model being simulated.
    pub profile: DeviceProfile,
    /// The shared (sim or real) timeline.
    pub clock: Clock,
    thermal: BTreeMap<EngineKind, ThermalModel>,
    loads: BTreeMap<EngineKind, f64>,
    noise: Rng,
    noise_sigma: f64,
}

impl DeviceSim {
    /// A cool, idle device on the given timeline.
    pub fn new(profile: DeviceProfile, clock: Clock) -> Self {
        let thermal = profile
            .engines
            .iter()
            .map(|e| (e.kind, ThermalModel::new(e.thermal.clone())))
            .collect();
        DeviceSim {
            profile,
            clock,
            thermal,
            loads: BTreeMap::new(),
            noise: Rng::new(0x0D1),
            noise_sigma: 0.03,
        }
    }

    /// Inject external load (co-running apps) on one engine.  Fig 7 ramps
    /// this; latency scales by 2^load, per the paper's own load model.
    pub fn set_load(&mut self, engine: EngineKind, load: f64) {
        self.loads.insert(engine, load.max(0.0));
    }

    /// Override the log-normal latency-jitter sigma (default 0.03).  Zero
    /// makes every simulated latency exactly the closed-form roofline value
    /// — the serve-bench harness relies on this for golden snapshots.
    pub fn set_noise_sigma(&mut self, sigma: f64) {
        self.noise_sigma = sigma.max(0.0);
    }

    /// Current external load factor on one engine.
    pub fn load(&self, engine: EngineKind) -> f64 {
        self.loads.get(&engine).copied().unwrap_or(0.0)
    }

    /// Current temperature of one engine (deg C), when present.
    pub fn temp_c(&self, engine: EngineKind) -> Option<f64> {
        self.thermal.get(&engine).map(|t| t.temp_c())
    }

    /// Current conditions snapshot (what middleware c transmits).
    pub fn conditions(&self) -> Conditions {
        let mut c = Conditions::idle();
        for (k, l) in &self.loads {
            c.loads.insert(*k, *l);
        }
        for (k, t) in &self.thermal {
            c.thermal.insert(*k, t.freq_scale());
        }
        c
    }

    /// Execute one inference of `variant` on `engine` under `governor` with
    /// `threads`: computes the conditioned latency, integrates heat, and
    /// advances a simulated clock by the latency.
    pub fn run_inference(&mut self, variant: &ModelVariant, engine: EngineKind,
                         threads: usize, governor: Governor) -> Result<SimExec> {
        let now = self.clock.now_ms();
        // Let the engine cool across any idle gap first.
        let tm = self
            .thermal
            .get_mut(&engine)
            .ok_or_else(|| anyhow!("{} has no {}", self.profile.name, engine.name()))?;
        tm.idle_until(now);
        let thermal_scale = tm.freq_scale();

        let cond = ExecConditions {
            governor,
            threads,
            load_factor: self.loads.get(&engine).copied().unwrap_or(0.0),
            thermal_freq_scale: thermal_scale,
        };
        let base = perf::latency_ms(&self.profile, engine, variant, &cond)
            .ok_or_else(|| anyhow!("no perf model for {}", engine.name()))?;
        let latency_ms = base * self.noise.lognormal(self.noise_sigma);

        // Busy time heats the engine; dispatch is host-side.
        let busy = perf::busy_ms(&self.profile, engine, variant, &cond).unwrap();
        if self.clock.is_sim() {
            self.clock.advance_ms(latency_ms);
        }
        tm.record_work(self.clock.now_ms(), busy, governor);

        Ok(SimExec { latency_ms, temp_c: tm.temp_c(), thermal_scale })
    }

    /// Execute one inference of `variant` as a *pipelined multi-engine
    /// partition* (`engines` per segment, interior cut points `cuts_pm`
    /// in per-mille): nominal per-stage costs come from
    /// [`perf::plan_stage_costs`], the steady-state latency is the
    /// bottleneck stage plus its inbound transfer, conditioned by the
    /// engines' current load/thermal state through
    /// [`perf::plan_condition_factor`].  Every touched engine is heated
    /// by its *own* stage's busy time — that per-engine accounting is the
    /// point of co-execution: no single engine absorbs the whole model's
    /// heat.  Returns the hottest touched engine's temperature and the
    /// lowest thermal scale in effect during the run.
    pub fn run_pipelined(&mut self, variant: &ModelVariant,
                         engines: &[EngineKind], cuts_pm: &[u32],
                         governor: Governor) -> Result<SimExec> {
        let now = self.clock.now_ms();
        for e in engines {
            let tm = self.thermal.get_mut(e).ok_or_else(|| {
                anyhow!("{} has no {}", self.profile.name, e.name())
            })?;
            tm.idle_until(now);
        }
        let stages =
            perf::plan_stage_costs(&self.profile, variant, engines, cuts_pm,
                                   governor)
                .ok_or_else(|| anyhow!("no partition cost model for plan"))?;
        let base = perf::pipelined_latency_ms(&stages);
        let thermal_now: BTreeMap<EngineKind, f64> = engines
            .iter()
            .map(|e| (*e, self.thermal[e].freq_scale()))
            .collect();
        let factor = perf::plan_condition_factor(
            &stages,
            |k| self.loads.get(&k).copied().unwrap_or(0.0),
            |k| thermal_now.get(&k).copied().unwrap_or(1.0),
        );
        let latency_ms = base * factor * self.noise.lognormal(self.noise_sigma);

        if self.clock.is_sim() {
            self.clock.advance_ms(latency_ms);
        }
        let t_end = self.clock.now_ms();
        let mut temp_c = f64::NEG_INFINITY;
        for st in &stages {
            let tm = self.thermal.get_mut(&st.engine).unwrap();
            tm.record_work(t_end, st.stage_ms, governor);
            temp_c = temp_c.max(tm.temp_c());
        }
        let thermal_scale = thermal_now
            .values()
            .fold(1.0f64, |a, &s| a.min(s));
        Ok(SimExec { latency_ms, temp_c, thermal_scale })
    }

    /// Advance idle time (no inference running) — cools all engines.
    pub fn idle(&mut self, ms: f64) {
        if self.clock.is_sim() {
            self.clock.advance_ms(ms);
        }
        let now = self.clock.now_ms();
        for t in self.thermal.values_mut() {
            t.idle_until(now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_a71;
    use crate::model::test_fixtures::fake_registry;

    fn variant(name: &str) -> ModelVariant {
        fake_registry().get(name).unwrap().clone()
    }

    #[test]
    fn inference_advances_sim_clock() {
        let mut sim = DeviceSim::new(samsung_a71(), Clock::sim());
        let v = variant("inception_v3__fp32__b1");
        let r = sim.run_inference(&v, EngineKind::Gpu, 1, Governor::Performance).unwrap();
        assert!(r.latency_ms > 0.0);
        assert!((sim.clock.now_ms() - r.latency_ms).abs() < 1e-3); // µs rounding
    }

    #[test]
    fn sustained_npu_work_heats_and_throttles() {
        let mut sim = DeviceSim::new(samsung_a71(), Clock::sim());
        let v = variant("inception_v3__fp32__b1"); // heavy + npu penalty-free? fp32 on NPU is slow -> long busy
        let mut first = None;
        let mut throttled = false;
        for _ in 0..900 {
            let r = sim.run_inference(&v, EngineKind::Npu, 1, Governor::Performance).unwrap();
            first.get_or_insert(r.latency_ms);
            if r.thermal_scale < 0.85 {
                // Deep in the throttle ramp the latency must have risen.
                throttled = true;
                assert!(r.latency_ms > first.unwrap() * 1.1,
                        "throttled latency {} vs first {}", r.latency_ms,
                        first.unwrap());
                break;
            }
        }
        assert!(throttled, "NPU never throttled; temp {:?}", sim.temp_c(EngineKind::Npu));
    }

    #[test]
    fn load_scales_latency_exponentially() {
        let mut sim = DeviceSim::new(samsung_a71(), Clock::sim());
        let v = variant("mobilenet_v2_100__fp32__b1");
        let base = sim.run_inference(&v, EngineKind::Cpu, 8, Governor::Performance).unwrap();
        sim.set_load(EngineKind::Cpu, 2.0);
        let loaded = sim.run_inference(&v, EngineKind::Cpu, 8, Governor::Performance).unwrap();
        let ratio = loaded.latency_ms / base.latency_ms;
        assert!((3.2..5.0).contains(&ratio), "ratio {ratio}"); // ~4x ± noise
    }

    #[test]
    fn idle_cools_engines() {
        let mut sim = DeviceSim::new(samsung_a71(), Clock::sim());
        let v = variant("inception_v3__fp32__b1");
        for _ in 0..200 {
            sim.run_inference(&v, EngineKind::Npu, 1, Governor::Performance).unwrap();
        }
        let hot = sim.temp_c(EngineKind::Npu).unwrap();
        sim.idle(60_000.0);
        assert!(sim.temp_c(EngineKind::Npu).unwrap() < hot - 5.0);
    }

    #[test]
    fn conditions_reflect_state() {
        let mut sim = DeviceSim::new(samsung_a71(), Clock::sim());
        sim.set_load(EngineKind::Gpu, 1.5);
        let c = sim.conditions();
        assert_eq!(c.load(EngineKind::Gpu), 1.5);
        assert_eq!(c.thermal_scale(EngineKind::Cpu), 1.0);
    }

    #[test]
    fn missing_engine_errors() {
        let mut sim = DeviceSim::new(crate::device::profiles::sony_c5(), Clock::sim());
        let v = variant("mobilenet_v2_100__fp32__b1");
        assert!(sim.run_inference(&v, EngineKind::Npu, 1, Governor::Performance).is_err());
    }

    #[test]
    fn pipelined_run_matches_closed_form_and_heats_all_stages() {
        let mut sim = DeviceSim::new(samsung_a71(), Clock::sim());
        sim.set_noise_sigma(0.0);
        let v = variant("deeplab_v3__int8__b1");
        let engines = [EngineKind::Gpu, EngineKind::Cpu];
        let cuts = [500u32];
        let stages = perf::plan_stage_costs(&sim.profile, &v, &engines, &cuts,
                                            Governor::Performance)
            .unwrap();
        let expect = perf::pipelined_latency_ms(&stages);
        let cool_gpu = sim.temp_c(EngineKind::Gpu).unwrap();
        let cool_cpu = sim.temp_c(EngineKind::Cpu).unwrap();
        let r = sim
            .run_pipelined(&v, &engines, &cuts, Governor::Performance)
            .unwrap();
        assert!((r.latency_ms - expect).abs() < 1e-9,
                "cool idle pipelined run {} vs closed form {expect}",
                r.latency_ms);
        for _ in 0..50 {
            sim.run_pipelined(&v, &engines, &cuts, Governor::Performance)
                .unwrap();
        }
        assert!(sim.temp_c(EngineKind::Gpu).unwrap() > cool_gpu,
                "gpu stage must heat the gpu");
        assert!(sim.temp_c(EngineKind::Cpu).unwrap() > cool_cpu,
                "cpu stage must heat the cpu");
    }

    #[test]
    fn pipelined_missing_engine_errors() {
        let mut sim = DeviceSim::new(crate::device::profiles::sony_c5(),
                                     Clock::sim());
        let v = variant("mobilenet_v2_100__fp32__b1");
        assert!(sim
            .run_pipelined(&v, &[EngineKind::Cpu, EngineKind::Npu], &[500],
                           Governor::Performance)
            .is_err());
    }
}
