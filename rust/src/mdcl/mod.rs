//! MDCL — Mobile Device Convergence Layer (paper §III-C2).
//!
//! The device-aware sublayer.  It identifies the resources of the target
//! platform (populating the resource model R of Eq. 2) and hosts the three
//! middlewares:
//!
//! * **Middleware a** — hardware information for SIL (camera capabilities,
//!   screen, engine inventory) used to configure the app's basic blocks.
//! * **Middleware b** — optional DNN-output-driven feature optimisation
//!   (e.g. adapting camera parameters based on the last scene class).
//! * **Middleware c** — system-statistics collection and transfer to the
//!   Runtime Manager, including throttling warnings.

use crate::device::{profiles, CameraSpec, DeviceProfile, EngineKind};
use crate::devicesim::DeviceSim;
use crate::manager::Conditions;

/// Resource detection: populate R for a known target platform.  On a real
/// build this would probe /proc, the NNAPI device list and the Camera2 API;
/// here it resolves the Table I profile (DESIGN.md §Substitutions).
pub fn detect(device_name: &str) -> anyhow::Result<DeviceProfile> {
    profiles::by_name(device_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown device `{device_name}` (have: {})",
            profiles::profiles().iter().map(|d| d.name).collect::<Vec<_>>().join(", ")
        )
    })
}

/// Render the populated resource model R like the paper's S20 FE example:
/// `CE={CPU,GPU,NPU}, N_cores=8, C=6GB, DVFS={...}, b=4500mAh, v_os=11, ...`.
pub fn format_resource_model(d: &DeviceProfile) -> String {
    let ce: Vec<&str> = d.engines.iter().map(|e| match e.kind {
        EngineKind::Cpu => "CPU",
        EngineKind::Gpu => "GPU",
        EngineKind::Npu => "NPU",
    }).collect();
    let govs: Vec<&str> = d.governors.iter().map(|g| g.name()).collect();
    format!(
        "CE={{{}}}, N_cores={}, C={}GB, DVFS={{{}}}, b={}mAh, v_os={}, v_camera={{{},{}x{}}}",
        ce.join(","), d.n_cores, d.ram_gb, govs.join(","), d.battery_mah,
        d.os_version, d.camera.api_level, d.camera.resolution.0,
        d.camera.resolution.1
    )
}

/// Middleware a: hardware info handed to SIL for app configuration.
#[derive(Debug, Clone)]
pub struct HardwareInfo {
    /// Camera capabilities (v_camera).
    pub camera: CameraSpec,
    /// Screen resolution.
    pub screen: (u32, u32),
    /// Available compute engines (CE).
    pub engines: Vec<EngineKind>,
}

/// Middleware a: collect the hardware info SIL configures itself from.
pub fn middleware_a(d: &DeviceProfile) -> HardwareInfo {
    HardwareInfo {
        camera: d.camera.clone(),
        screen: d.camera.resolution,
        engines: d.engines.iter().map(|e| e.kind).collect(),
    }
}

/// Middleware b: DNN-output-driven feature tuning.  The hook receives the
/// last inference's (class, confidence) and may emit feature adjustments —
/// the paper's example is an AI Camera adapting brightness to the detected
/// scene.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureAdjustment {
    /// New camera exposure multiplier.
    pub camera_exposure: f64,
}

/// Middleware b: map the last (class, confidence) to feature adjustments.
pub fn middleware_b(last_class: usize, confidence: f32) -> Option<FeatureAdjustment> {
    // Low-confidence scenes get a small exposure bump; "night-ish" classes
    // (by convention the upper half of the label space) a larger one.
    if confidence < 0.2 {
        Some(FeatureAdjustment { camera_exposure: 1.2 })
    } else if last_class >= 5 {
        Some(FeatureAdjustment { camera_exposure: 1.1 })
    } else {
        None
    }
}

/// A warning raised by middleware c alongside periodic statistics.
#[derive(Debug, Clone, PartialEq)]
pub enum Warning {
    /// An engine is thermally throttling.
    Throttling {
        /// The throttling engine.
        engine: EngineKind,
        /// Its temperature (deg C).
        temp_c: f64,
    },
    /// Resident model memory exceeds the device budget.
    MemoryPressure {
        /// Bytes currently resident.
        used: u64,
        /// Device budget (bytes).
        budget: u64,
    },
}

/// One statistics report transmitted to the Runtime Manager.
#[derive(Debug, Clone)]
pub struct StatsReport {
    /// Device-timeline instant of the report (ms).
    pub at_ms: f64,
    /// Per-engine load/thermal conditions.
    pub conditions: Conditions,
    /// Raised warnings, if any.
    pub warnings: Vec<Warning>,
}

/// Middleware c: collect per-engine load/thermal statistics from the device
/// and raise throttling warnings.
pub fn middleware_c(sim: &DeviceSim, resident_bytes: u64) -> StatsReport {
    let conditions = sim.conditions();
    let mut warnings = Vec::new();
    for e in &sim.profile.engines {
        if conditions.thermal_scale(e.kind) < 1.0 {
            warnings.push(Warning::Throttling {
                engine: e.kind,
                temp_c: sim.temp_c(e.kind).unwrap_or(f64::NAN),
            });
        }
    }
    if resident_bytes > sim.profile.mem_budget_bytes {
        warnings.push(Warning::MemoryPressure {
            used: resident_bytes,
            budget: sim.profile.mem_budget_bytes,
        });
    }
    StatsReport { at_ms: sim.clock.now_ms(), conditions, warnings }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::samsung_s20_fe;
    use crate::dvfs::Governor;
    use crate::model::test_fixtures::fake_registry;
    use crate::util::clock::Clock;

    #[test]
    fn detect_known_devices() {
        assert!(detect("sony_c5").is_ok());
        assert!(detect("samsung_a71").is_ok());
        let err = detect("iphone_12").unwrap_err().to_string();
        assert!(err.contains("samsung_s20_fe"), "{err}");
    }

    #[test]
    fn resource_model_matches_paper_example() {
        // Paper §III-C2: S20 FE -> CE={CPU,GPU,NPU}, N_cores=8, C=6GB,
        // DVFS={energy_step,performance,schedutil}, b=4500mAh, v_os=11, FULL.
        let s = format_resource_model(&samsung_s20_fe());
        assert!(s.contains("CE={CPU,GPU,NPU}"), "{s}");
        assert!(s.contains("N_cores=8"), "{s}");
        assert!(s.contains("C=6GB"), "{s}");
        assert!(s.contains("energy_step"), "{s}");
        assert!(s.contains("b=4500mAh"), "{s}");
        assert!(s.contains("v_os=11"), "{s}");
        assert!(s.contains("FULL"), "{s}");
    }

    #[test]
    fn middleware_a_exposes_engine_inventory() {
        let info = middleware_a(&samsung_s20_fe());
        assert_eq!(info.engines.len(), 3);
        assert_eq!(info.camera.api_level, "FULL");
    }

    #[test]
    fn middleware_b_rules() {
        assert!(middleware_b(1, 0.9).is_none());
        assert_eq!(middleware_b(7, 0.9),
                   Some(FeatureAdjustment { camera_exposure: 1.1 }));
        assert_eq!(middleware_b(1, 0.1),
                   Some(FeatureAdjustment { camera_exposure: 1.2 }));
    }

    #[test]
    fn middleware_c_raises_throttle_warning() {
        let mut sim = DeviceSim::new(crate::device::profiles::samsung_a71(),
                                     Clock::sim());
        let reg = fake_registry();
        let v = reg.get("inception_v3__fp32__b1").unwrap().clone();
        // Cold: no warnings.
        let cold = middleware_c(&sim, 0);
        assert!(cold.warnings.is_empty());
        // Hammer the NPU until it throttles.
        for _ in 0..600 {
            sim.run_inference(&v, EngineKind::Npu, 1, Governor::Performance).unwrap();
        }
        let hot = middleware_c(&sim, 0);
        assert!(hot.warnings.iter().any(|w| matches!(
            w, Warning::Throttling { engine: EngineKind::Npu, .. })));
    }

    #[test]
    fn middleware_c_memory_pressure() {
        let sim = DeviceSim::new(crate::device::profiles::sony_c5(), Clock::sim());
        let r = middleware_c(&sim, u64::MAX);
        assert!(r.warnings.iter().any(|w| matches!(w, Warning::MemoryPressure { .. })));
    }
}
