//! SIL — Service-Independent Layer (paper §III-C1).
//!
//! App-level building blocks, agnostic of both the DNN and the device: a
//! camera interface for real-time visual apps, a local gallery database for
//! processed results, and UI components.  Packaged under one API so smart
//! applications compose them (paper: camera + local DB + UI under a unified
//! API).

pub mod camera;
pub mod gallery;
pub mod ui;

pub use camera::{Frame, SyntheticCamera};
pub use gallery::{Gallery, GalleryEntry};
pub use ui::UiStub;
