//! Camera interface (SIL block).  On Android this wraps Camera2; here it is
//! a synthetic source producing the same class-conditional ring-blob scenes
//! as the Python validation dataset (`compile/datasets.py`), with known
//! ground-truth labels — so the end-to-end examples can measure real on-line
//! accuracy through the full stack.

use crate::util::rng::Rng;

/// Number of synthetic scene classes.
pub const NUM_CLASSES: usize = 10;

/// Class-blob dominant-channel weight — shared with the SimBackend
/// matched filter so generator and decoder stay in lockstep.
pub const BLOB_AMP: f32 = 1.5;
/// Class-blob secondary-channel weight (see [`BLOB_AMP`]).
pub const BLOB_SECONDARY: f32 = 0.5;

/// Scene-template geometry shared by the frame generator and the
/// SimBackend matched filter (`runtime::sim::decode_scene`): the class
/// blob's ring-position centre `(cy, cx)` and gaussian `sigma`.
pub fn class_template(res: usize, label: usize) -> (f64, f64, f64) {
    let c0 = res as f64 / 2.0;
    let r0 = res as f64 * 0.30;
    let ang = 2.0 * std::f64::consts::PI * label as f64 / NUM_CLASSES as f64;
    (c0 + r0 * ang.sin(), c0 + r0 * ang.cos(), res as f64 * 0.10)
}

/// One captured RGB frame (HWC, f32).
#[derive(Debug, Clone)]
pub struct Frame {
    /// RGB pixels, HWC layout.
    pub data: Vec<f32>,
    /// Height in pixels.
    pub height: usize,
    /// Width in pixels.
    pub width: usize,
    /// Ground-truth class of the synthetic scene.
    pub label: usize,
    /// Capture timestamp on the device timeline (ms).
    pub ts_ms: f64,
    /// Monotone capture sequence number.
    pub seq: u64,
}

/// Synthetic Camera2 stand-in: frames at a fixed rate and resolution.
pub struct SyntheticCamera {
    /// Configured capture rate (frames/s).
    pub fps: f64,
    /// Square frame resolution (pixels per side).
    pub resolution: usize,
    /// Exposure multiplier (middleware-b adjusts it).
    pub exposure: f64,
    noise: f64,
    rng: Rng,
    seq: u64,
}

impl SyntheticCamera {
    /// A camera producing `resolution`-square frames at `fps`, seeded.
    pub fn new(resolution: usize, fps: f64, seed: u64) -> Self {
        SyntheticCamera { fps, resolution, exposure: 1.0, noise: 0.95,
                          rng: Rng::new(seed), seq: 0 }
    }

    /// Frame interval on the device timeline.
    pub fn frame_interval_ms(&self) -> f64 {
        1000.0 / self.fps
    }

    /// Capture the next frame at device-time `ts_ms` (mirrors
    /// `datasets.make_classification`: class blob on a ring + distractors +
    /// noise).
    pub fn capture(&mut self, ts_ms: f64) -> Frame {
        let res = self.resolution;
        let label = self.rng.below(NUM_CLASSES);
        let mut data = vec![0.0f32; res * res * 3];
        let (tcy, tcx, sigma) = class_template(res, label);
        let cy = tcy + self.rng.normal() * res as f64 * 0.03;
        let cx = tcx + self.rng.normal() * res as f64 * 0.03;
        let dom = label % 3;
        self.add_blob(&mut data, cy, cx, sigma, dom, BLOB_AMP);

        // Two distractor blobs with random colours.
        for _ in 0..2 {
            let dy = self.rng.range(0.0, res as f64);
            let dx = self.rng.range(0.0, res as f64);
            let col = [self.rng.range(0.4, 1.2), self.rng.range(0.4, 1.2),
                       self.rng.range(0.4, 1.2)];
            self.add_coloured_blob(&mut data, dy, dx, res as f64 * 0.09, col);
        }
        // Sensor noise scaled by exposure.
        for v in data.iter_mut() {
            *v = (*v + self.rng.normal() as f32 * self.noise as f32)
                * self.exposure as f32;
        }
        self.seq += 1;
        Frame { data, height: res, width: res, label, ts_ms, seq: self.seq }
    }

    fn add_blob(&mut self, data: &mut [f32], cy: f64, cx: f64, sigma: f64,
                dom: usize, amp: f32) {
        let res = self.resolution;
        for y in 0..res {
            for x in 0..res {
                let d2 = (y as f64 - cy).powi(2) + (x as f64 - cx).powi(2);
                let g = (-d2 / (2.0 * sigma * sigma)).exp() as f32;
                let i = (y * res + x) * 3;
                data[i + dom] += amp * g;
                data[i + (dom + 1) % 3] += BLOB_SECONDARY * g;
            }
        }
    }

    fn add_coloured_blob(&mut self, data: &mut [f32], cy: f64, cx: f64,
                         sigma: f64, col: [f64; 3]) {
        let res = self.resolution;
        for y in 0..res {
            for x in 0..res {
                let d2 = (y as f64 - cy).powi(2) + (x as f64 - cx).powi(2);
                let g = (-d2 / (2.0 * sigma * sigma)).exp();
                let i = (y * res + x) * 3;
                for c in 0..3 {
                    data[i + c] += (g * col[c]) as f32;
                }
            }
        }
    }
}

/// A clean class-conditional frame (no noise, no distractors): just the
/// class blob at its ring position with the dominant-channel pattern.
/// Deterministic — used by backend/serving tests that need frames whose
/// decoded class is exact.
pub fn class_frame(res: usize, label: usize) -> Vec<f32> {
    let mut data = vec![0.0f32; res * res * 3];
    let (cy, cx, sigma) = class_template(res, label);
    let dom = label % 3;
    for y in 0..res {
        for x in 0..res {
            let d2 = (y as f64 - cy).powi(2) + (x as f64 - cx).powi(2);
            let g = (-d2 / (2.0 * sigma * sigma)).exp() as f32;
            let i = (y * res + x) * 3;
            data[i + dom] += BLOB_AMP * g;
            data[i + (dom + 1) % 3] += BLOB_SECONDARY * g;
        }
    }
    data
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_have_right_shape_and_labels() {
        let mut cam = SyntheticCamera::new(24, 30.0, 7);
        for t in 0..20 {
            let f = cam.capture(t as f64 * 33.3);
            assert_eq!(f.data.len(), 24 * 24 * 3);
            assert!(f.label < NUM_CLASSES);
            assert_eq!(f.seq, t + 1);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = SyntheticCamera::new(16, 30.0, 3);
        let mut b = SyntheticCamera::new(16, 30.0, 3);
        let fa = a.capture(0.0);
        let fb = b.capture(0.0);
        assert_eq!(fa.data, fb.data);
        assert_eq!(fa.label, fb.label);
    }

    #[test]
    fn signal_is_at_class_ring_position() {
        // With noise suppressed, the class blob beats the opposite point.
        let mut cam = SyntheticCamera::new(24, 30.0, 11);
        cam.noise = 0.0;
        let mut hits = 0;
        let n = 100;
        for _ in 0..n {
            let f = cam.capture(0.0);
            let res = 24usize;
            let ang = 2.0 * std::f64::consts::PI * f.label as f64 / 10.0;
            let cy = (12.0 + 7.2 * ang.sin()).round() as usize;
            let cx = (12.0 + 7.2 * ang.cos()).round() as usize;
            let sum = |y: usize, x: usize| -> f32 {
                let i = (y.min(23) * res + x.min(23)) * 3;
                f.data[i] + f.data[i + 1] + f.data[i + 2]
            };
            if sum(cy, cx) > sum(23 - cy, 23 - cx) {
                hits += 1;
            }
        }
        assert!(hits > 75, "{hits}/{n}");
    }

    #[test]
    fn exposure_scales_frame() {
        let mut cam = SyntheticCamera::new(8, 30.0, 5);
        cam.noise = 0.0;
        cam.exposure = 2.0;
        let f2 = cam.capture(0.0);
        let mut cam1 = SyntheticCamera::new(8, 30.0, 5);
        cam1.noise = 0.0;
        let f1 = cam1.capture(0.0);
        assert_eq!(f1.label, f2.label);
        for (a, b) in f1.data.iter().zip(&f2.data) {
            assert!((a * 2.0 - b).abs() < 1e-5);
        }
    }

    #[test]
    fn frame_interval() {
        let cam = SyntheticCamera::new(8, 25.0, 0);
        assert!((cam.frame_interval_ms() - 40.0).abs() < 1e-9);
    }
}
