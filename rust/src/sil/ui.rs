//! UI components (SIL block).  A terminal-backed stand-in for the app's
//! view layer: status lines, a live config banner and an event log that the
//! examples render.  Kept behind the same narrow interface an Android view
//! model would implement.

/// Collected UI state.
#[derive(Debug, Default)]
pub struct UiStub {
    /// Current configuration banner text.
    pub banner: String,
    /// Event log, oldest first.
    pub events: Vec<String>,
    /// When true, events are echoed to stdout as they arrive.
    pub live: bool,
}

impl UiStub {
    /// A fresh UI; `live` echoes events to stdout.
    pub fn new(live: bool) -> Self {
        UiStub { live, ..Default::default() }
    }

    /// Show the active configuration (model + engine + params).
    pub fn set_banner(&mut self, text: impl Into<String>) {
        self.banner = text.into();
        if self.live {
            println!("[ui] {}", self.banner);
        }
    }

    /// Append an event line (switch notifications, warnings, results).
    pub fn event(&mut self, text: impl Into<String>) {
        let text = text.into();
        if self.live {
            println!("[ui] {text}");
        }
        self.events.push(text);
    }

    /// Most recent event line, if any.
    pub fn last_event(&self) -> Option<&str> {
        self.events.last().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_events_and_banner() {
        let mut ui = UiStub::new(false);
        ui.set_banner("mobilenet @ nnapi");
        ui.event("switched to gpu");
        ui.event("frame 10 done");
        assert_eq!(ui.banner, "mobilenet @ nnapi");
        assert_eq!(ui.events.len(), 2);
        assert_eq!(ui.last_event(), Some("frame 10 done"));
    }
}
