//! Local gallery database (SIL block).  On Android this is the Room
//! library; here an append-only JSON-lines store with the same role:
//! persisting the app's labelled photos (paper's smart-Gallery example).

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{self, Value};

/// One stored record: a processed frame's label and metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct GalleryEntry {
    /// Capture timestamp on the device timeline (ms).
    pub ts_ms: f64,
    /// Frame sequence number.
    pub seq: u64,
    /// Top-1 class the resident model predicted.
    pub predicted_class: usize,
    /// Top-1 score.
    pub confidence: f64,
    /// Variant that produced the prediction.
    pub model: String,
    /// Engine it ran on.
    pub engine: String,
}

impl GalleryEntry {
    fn to_json(&self) -> Value {
        json::obj(vec![
            ("ts_ms", json::num(self.ts_ms)),
            ("seq", json::num(self.seq as f64)),
            ("class", json::num(self.predicted_class as f64)),
            ("confidence", json::num(self.confidence)),
            ("model", json::s(&self.model)),
            ("engine", json::s(&self.engine)),
        ])
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(GalleryEntry {
            ts_ms: v.req("ts_ms")?.as_f64()?,
            seq: v.req("seq")?.as_u64()?,
            predicted_class: v.req("class")?.as_usize()?,
            confidence: v.req("confidence")?.as_f64()?,
            model: v.req("model")?.as_str()?.to_string(),
            engine: v.req("engine")?.as_str()?.to_string(),
        })
    }
}

/// Append-only gallery store.
pub struct Gallery {
    path: PathBuf,
    file: std::fs::File,
    count: u64,
}

impl Gallery {
    /// Open (or create) the gallery at `path`.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).ok();
        }
        let count = if path.exists() {
            std::fs::read_to_string(&path)?.lines().count() as u64
        } else {
            0
        };
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening gallery {}", path.display()))?;
        Ok(Gallery { path, file, count })
    }

    /// In-memory-ish gallery for tests/benches (unique temp file).
    pub fn temp(tag: &str) -> Result<Self> {
        let path = std::env::temp_dir()
            .join("oodin_gallery")
            .join(format!("{tag}_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        Self::open(path)
    }

    /// Append one record.
    pub fn add(&mut self, entry: &GalleryEntry) -> Result<()> {
        let mut line = json::to_string(&entry.to_json());
        line.push('\n');
        self.file.write_all(line.as_bytes())?;
        self.count += 1;
        Ok(())
    }

    /// Stored record count.
    pub fn len(&self) -> u64 {
        self.count
    }

    /// True when nothing is stored.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Read back all entries (oldest first).
    pub fn load_all(&mut self) -> Result<Vec<GalleryEntry>> {
        self.file.flush()?;
        let text = std::fs::read_to_string(&self.path)?;
        text.lines()
            .map(|l| GalleryEntry::from_json(&json::parse(l)?))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(seq: u64, class: usize) -> GalleryEntry {
        GalleryEntry {
            ts_ms: seq as f64 * 33.3,
            seq,
            predicted_class: class,
            confidence: 0.75,
            model: "mobilenet_v2_100__int8__b1".into(),
            engine: "nnapi".into(),
        }
    }

    #[test]
    fn add_and_load_roundtrip() {
        let mut g = Gallery::temp("roundtrip").unwrap();
        assert!(g.is_empty());
        for i in 0..5 {
            g.add(&entry(i, i as usize % 3)).unwrap();
        }
        assert_eq!(g.len(), 5);
        let back = g.load_all().unwrap();
        assert_eq!(back.len(), 5);
        assert_eq!(back[3], entry(3, 0));
    }

    #[test]
    fn reopen_preserves_count() {
        let path = std::env::temp_dir()
            .join("oodin_gallery")
            .join(format!("reopen_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let mut g = Gallery::open(&path).unwrap();
            g.add(&entry(1, 1)).unwrap();
            g.add(&entry(2, 2)).unwrap();
        }
        let g2 = Gallery::open(&path).unwrap();
        assert_eq!(g2.len(), 2);
        let _ = std::fs::remove_file(&path);
    }
}
