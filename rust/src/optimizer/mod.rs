//! System Optimisation (paper §III-D): multi-objective selection of the
//! design σ = <m_ref, t, hw> by complete enumerative search over the
//! measured look-up tables.
//!
//! Performance metrics P = {T, fps, mem, a}.  The three representative
//! use-cases of Eq. (3)–(5) are implemented exactly:
//!
//! * `MaxFps` — ε-constraint: max fps s.t. accuracy drop ≤ ε.
//! * `TargetLatency` — ε-constraint: max accuracy s.t. T ≤ T_target.
//! * `MaxAccMaxFps` — weighted sum of accuracy and fps, both normalised by
//!   the max observed over the candidate space (non-dimensional objective).
//!
//! plus `MinLatency` (min T s.t. accuracy drop ≤ ε), the objective the
//! paper's Fig 3–6 evaluations use.  `SearchSpace` restrictions express the
//! baselines (oSQ-CPU/-GPU/-NNAPI restrict the engine set; PAW-D / MAW-D
//! transplant configurations — see `experiments/`).
//!
//! Since the design-space refactor the enumeration, constraint
//! pre-filtering and selection order live in [`crate::designspace`]
//! (shared with the Runtime Manager's frontier walk and the multi-app
//! joint search); this module keeps the paper-facing API.  Ties in an
//! objective's score resolve along the canonical chain (energy ↑,
//! latency ↑, accuracy ↓, recognition rate ↓, memory ↑), so e.g. a
//! weighted-sum tie now breaks toward the lowest-energy design.

use anyhow::{anyhow, Result};

use crate::designspace::{rank, DesignSpace};
use crate::device::{DeviceProfile, EngineKind};
use crate::dvfs::Governor;
use crate::manager::Conditions;
use crate::measurements::{entry_energy_mj, ExecPlan, Lut, LutKey};
use crate::model::{Precision, Registry};
use crate::util::stats::Percentile;

pub use crate::designspace::Candidate as Evaluated;

/// Recognition-rate candidates r (inference invocation frequency, §III-B1).
pub const RECOGNITION_RATES: [f64; 3] = [1.0, 0.5, 0.25];

/// The tunable system-level parameters hw = <ce, N_threads, g, r, π>.
#[derive(Debug, Clone, PartialEq)]
pub struct HwConfig {
    /// ce: the engine the model runs on (first-stage engine when
    /// partitioned).
    pub engine: EngineKind,
    /// N_threads: CPU threads (1 for offload engines).
    pub threads: usize,
    /// g: the DVFS governor.
    pub governor: Governor,
    /// r: fraction of camera frames actually processed.
    pub recognition_rate: f64,
    /// π: monolithic execution or a pipelined multi-engine partition
    /// (the co-execution extension of the σ design vector).
    pub plan: ExecPlan,
}

/// A candidate design σ = <m_ref, t, hw>: the variant name encodes
/// (m_ref, t) as `<family>__<precision>__b1`.
#[derive(Debug, Clone, PartialEq)]
pub struct Design {
    /// Variant name encoding (m_ref, t).
    pub variant: String,
    /// The system-parameter half of σ.
    pub hw: HwConfig,
}

impl Design {
    /// The LUT configuration this design reads its measurements from.
    pub fn lut_key(&self) -> LutKey {
        LutKey {
            variant: self.variant.clone(),
            engine: self.hw.engine,
            threads: self.hw.threads,
            governor: self.hw.governor,
            plan: self.hw.plan.clone(),
        }
    }

    /// Every engine this design occupies while running: one for a
    /// monolithic design, all pipeline stages for a partitioned one.
    /// Exclusive-engine budgets (joint search) and per-engine
    /// availability checks must treat a partitioned design as holding
    /// each of these.
    pub fn engines(&self) -> Vec<EngineKind> {
        self.hw.plan.engines(self.hw.engine)
    }
}

/// The user-specified optimisation objective o_i = <P, max/min/val(stat)>.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Eq. (3): max fps s.t. a_ref − a ≤ ε.
    MaxFps {
        /// Tolerated accuracy drop ε.
        epsilon: f64,
    },
    /// Eq. (4): max accuracy s.t. T(stat) ≤ t_target_ms.
    TargetLatency {
        /// Latency budget (ms).
        t_target_ms: f64,
        /// Statistic the budget constrains.
        stat: Percentile,
    },
    /// Eq. (5): max a/a_max + w_fps · fps/fps_max.
    MaxAccMaxFps {
        /// Weight of the fps term.
        w_fps: f64,
    },
    /// Fig 3–6: min T(stat) s.t. a_ref − a ≤ ε.
    MinLatency {
        /// Statistic being minimised.
        stat: Percentile,
        /// Tolerated accuracy drop ε.
        epsilon: f64,
    },
}

impl Objective {
    /// The latency statistic this objective reads from the LUT.
    pub fn stat(&self) -> Percentile {
        match self {
            Objective::TargetLatency { stat, .. } => *stat,
            Objective::MinLatency { stat, .. } => *stat,
            _ => Percentile::Avg,
        }
    }
}

/// Restrictions on the candidate space (used for baselines and by the
/// Runtime Manager to pin the model family the app was built around).
#[derive(Debug, Clone, Default)]
pub struct SearchSpace {
    /// Restrict to one model family (the "user-supplied DNN" case).
    pub family: Option<String>,
    /// Restrict engines (oSQ-D baselines).
    pub engines: Option<Vec<EngineKind>>,
    /// Restrict transformations.
    pub precisions: Option<Vec<Precision>>,
    /// Fix the recognition rate.
    pub recognition_rate: Option<f64>,
}

impl SearchSpace {
    /// Restrict to one model family, everything else free.
    pub fn family(name: &str) -> Self {
        SearchSpace { family: Some(name.to_string()), ..Default::default() }
    }

    /// Restrict the engine set.
    pub fn with_engines(mut self, engines: &[EngineKind]) -> Self {
        self.engines = Some(engines.to_vec());
        self
    }

    /// Restrict the transformation set.
    pub fn with_precisions(mut self, precisions: &[Precision]) -> Self {
        self.precisions = Some(precisions.to_vec());
        self
    }

    /// True when a LUT configuration passes this restriction (the
    /// design-space layer's pre-filter hook).
    pub fn admits(&self, reg: &Registry, key: &LutKey) -> bool {
        let Some(v) = reg.get(&key.variant) else { return false };
        if let Some(f) = &self.family {
            if &v.family != f {
                return false;
            }
        }
        if let Some(es) = &self.engines {
            // A partitioned key is admitted only when *every* engine it
            // touches is allowed (an oSQ-CPU baseline must not smuggle
            // GPU time in via a split plan).
            if !key.plan.engines(key.engine).iter().all(|e| es.contains(e)) {
                return false;
            }
        }
        if let Some(ps) = &self.precisions {
            if !ps.contains(&v.precision) {
                return false;
            }
        }
        true
    }
}

/// The System Optimisation module.
pub struct Optimizer<'a> {
    /// Target device.
    pub device: &'a DeviceProfile,
    /// Model space M.
    pub registry: &'a Registry,
    /// Device measurements driving the search.
    pub lut: &'a Lut,
    /// Camera/source frame rate bounding effective fps.
    pub camera_fps: f64,
}

impl<'a> Optimizer<'a> {
    /// An optimiser over (device, registry, LUT) at the default 30 fps.
    pub fn new(device: &'a DeviceProfile, registry: &'a Registry, lut: &'a Lut)
               -> Self {
        Optimizer { device, registry, lut, camera_fps: 30.0 }
    }

    /// Override the camera/source frame rate.
    pub fn with_camera_fps(mut self, fps: f64) -> Self {
        self.camera_fps = fps;
        self
    }

    /// Reference accuracy a_ref for a family: its FP32 (identity-
    /// transformation) variant.
    pub fn reference_accuracy(&self, family: &str) -> Option<f64> {
        self.registry
            .find(family, Precision::Fp32, 1)
            .map(|v| v.accuracy)
    }

    /// This optimiser's view of the unified design-space layer.
    fn design_space(&self) -> DesignSpace<'a> {
        DesignSpace {
            device: self.device,
            registry: self.registry,
            lut: self.lut,
            camera_fps: self.camera_fps,
        }
    }

    /// Enumerate, filter (deployability + ε-constraints) and score every
    /// candidate; returns them best-first under the canonical selection
    /// order.  This is the paper's "complete enumerative search over the
    /// populated look-up tables", now delegated to
    /// [`crate::designspace::DesignSpace::enumerate`] +
    /// [`crate::designspace::rank`] so every layer searches identically.
    pub fn search(&self, objective: Objective, space: &SearchSpace)
                  -> Result<Vec<Evaluated>> {
        let cands = self
            .design_space()
            .enumerate(objective, space, &Conditions::idle());
        if cands.is_empty() {
            return Err(anyhow!(
                "no deployable design for objective {objective:?} on {}",
                self.device.name
            ));
        }
        let scored = rank(cands, objective);
        if scored.is_empty() {
            return Err(anyhow!("no design satisfies {objective:?}"));
        }
        Ok(scored)
    }

    /// The single highest-performing design (paper: "yields the design σ
    /// that optimises the given use-case").
    pub fn optimize(&self, objective: Objective, space: &SearchSpace)
                    -> Result<Evaluated> {
        Ok(self.search(objective, space)?.remove(0))
    }

    /// Evaluate one *fixed* design under this device's LUT (used to score
    /// transplanted PAW-D / MAW-D configurations and the Runtime Manager's
    /// current design).
    pub fn evaluate(&self, design: &Design, stat: Percentile) -> Result<Evaluated> {
        let entry = self
            .lut
            .get(&design.lut_key())
            .ok_or_else(|| anyhow!("design {:?} not in LUT (engine absent?)", design))?;
        let energy_mj = entry_energy_mj(self.device, design.hw.engine, entry,
                                        design.hw.governor)
            .ok_or_else(|| anyhow!("device {} has no engine {}",
                                   self.device.name, design.hw.engine.name()))?;
        let r = design.hw.recognition_rate;
        Ok(Evaluated {
            design: design.clone(),
            latency_ms: entry.latency.metric(stat),
            avg_latency_ms: entry.latency.avg,
            fps: (self.camera_fps * r).min(1000.0 / entry.latency.avg),
            mem_bytes: entry.mem_bytes,
            accuracy: entry.accuracy,
            energy_mj,
            score: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::profiles::{samsung_a71, samsung_s20_fe, sony_c5};
    use crate::measurements::Measurer;
    use crate::model::test_fixtures::fake_registry;
    use crate::model::Registry;

    fn setup(dev: &DeviceProfile, reg: &Registry) -> Lut {
        Measurer::new(dev, reg).with_runs(40, 2).measure_all().unwrap()
    }

    #[test]
    fn min_latency_beats_every_single_engine() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = setup(&dev, &reg);
        let opt = Optimizer::new(&dev, &reg, &lut);
        let obj = Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.02 };
        let space = SearchSpace::family("mobilenet_v2_100");
        let best = opt.optimize(obj, &space).unwrap();
        for kind in EngineKind::ALL {
            if !dev.has_engine(kind) {
                continue;
            }
            let restricted = space.clone().with_engines(&[kind]);
            let b = opt.optimize(obj, &restricted).unwrap();
            assert!(best.latency_ms <= b.latency_ms + 1e-9,
                    "free search worse than {kind:?}-only");
        }
    }

    #[test]
    fn epsilon_zero_forbids_lossy_variants() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = setup(&dev, &reg);
        let opt = Optimizer::new(&dev, &reg, &lut);
        // fake manifest: int8 accuracy 0.885 < fp32 0.90
        let strict = Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.0 };
        let best = opt.optimize(strict, &SearchSpace::family("mobilenet_v2_100")).unwrap();
        let v = reg.get(&best.design.variant).unwrap();
        assert_eq!(v.precision, Precision::Fp32);

        let loose = Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 };
        let best = opt.optimize(loose, &SearchSpace::family("mobilenet_v2_100")).unwrap();
        let v = reg.get(&best.design.variant).unwrap();
        assert_eq!(v.precision, Precision::Int8, "int8 is fastest when allowed");
    }

    #[test]
    fn target_latency_maximises_accuracy_within_budget() {
        let dev = samsung_s20_fe();
        let reg = fake_registry();
        let lut = setup(&dev, &reg);
        let opt = Optimizer::new(&dev, &reg, &lut);
        let space = SearchSpace::default();
        // Generous budget: must pick the most accurate deployable variant.
        let relaxed = opt
            .optimize(Objective::TargetLatency {
                t_target_ms: 1e9,
                stat: Percentile::Avg,
            }, &space)
            .unwrap();
        let max_acc = relaxed.accuracy;
        // Tight budget: accuracy can only drop.
        let tight = opt
            .optimize(Objective::TargetLatency {
                t_target_ms: relaxed.latency_ms.max(0.05),
                stat: Percentile::Avg,
            }, &space);
        if let Ok(t) = tight {
            assert!(t.accuracy <= max_acc + 1e-12);
            assert!(t.latency_ms <= relaxed.latency_ms.max(0.05) + 1e-12);
        }
    }

    #[test]
    fn target_latency_infeasible_errors() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = setup(&dev, &reg);
        let opt = Optimizer::new(&dev, &reg, &lut);
        let r = opt.optimize(Objective::TargetLatency {
            t_target_ms: 1e-7,
            stat: Percentile::Avg,
        }, &SearchSpace::default());
        assert!(r.is_err());
    }

    #[test]
    fn max_fps_bounded_by_camera_and_rate() {
        let dev = samsung_s20_fe();
        let reg = fake_registry();
        let lut = setup(&dev, &reg);
        let opt = Optimizer::new(&dev, &reg, &lut).with_camera_fps(30.0);
        let best = opt
            .optimize(Objective::MaxFps { epsilon: 0.05 }, &SearchSpace::default())
            .unwrap();
        assert!(best.fps <= 30.0 + 1e-9);
        assert_eq!(best.design.hw.recognition_rate, 1.0,
                   "fast device: full-rate recognition is optimal");
    }

    #[test]
    fn weighted_sum_tradeoff_monotone_in_weight() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = setup(&dev, &reg);
        let opt = Optimizer::new(&dev, &reg, &lut).with_camera_fps(1000.0);
        let acc_heavy = opt
            .optimize(Objective::MaxAccMaxFps { w_fps: 0.05 }, &SearchSpace::default())
            .unwrap();
        let fps_heavy = opt
            .optimize(Objective::MaxAccMaxFps { w_fps: 20.0 }, &SearchSpace::default())
            .unwrap();
        assert!(fps_heavy.fps >= acc_heavy.fps - 1e-9);
        assert!(acc_heavy.accuracy >= fps_heavy.accuracy - 1e-9);
    }

    #[test]
    fn sony_rejects_oversized_models() {
        // Make one family exceed Sony's scaled memory budget.
        let dev = sony_c5();
        let manifest = crate::model::test_fixtures::fake_manifest()
            .replace(r#""size_bytes":400000,"flops":90000000"#,
                     r#""size_bytes":9000000,"flops":90000000"#);
        let reg = Registry::from_manifest_json(&manifest, "/tmp/fake".into()).unwrap();
        let lut = setup(&dev, &reg);
        let opt = Optimizer::new(&dev, &reg, &lut);
        let r = opt.optimize(
            Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.1 },
            &SearchSpace::family("inception_v3"),
        );
        // fp32 inception no longer fits; int8/fp16 still deployable.
        if let Ok(best) = r {
            let v = reg.get(&best.design.variant).unwrap();
            assert_ne!(v.precision, Precision::Fp32);
        }
    }

    #[test]
    fn evaluate_fixed_design_matches_lut() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = setup(&dev, &reg);
        let opt = Optimizer::new(&dev, &reg, &lut);
        let best = opt
            .optimize(Objective::MinLatency { stat: Percentile::P90, epsilon: 0.05 },
                      &SearchSpace::family("deeplab_v3"))
            .unwrap();
        let re = opt.evaluate(&best.design, Percentile::P90).unwrap();
        assert!((re.latency_ms - best.latency_ms).abs() < 1e-12);
    }

    #[test]
    fn evaluate_missing_engine_errors() {
        let dev = sony_c5();
        let reg = fake_registry();
        let lut = setup(&dev, &reg);
        let opt = Optimizer::new(&dev, &reg, &lut);
        let d = Design {
            variant: "mobilenet_v2_100__fp32__b1".into(),
            hw: HwConfig {
                engine: EngineKind::Npu, // Sony has no NPU
                threads: 1,
                governor: Governor::Performance,
                recognition_rate: 1.0,
                plan: ExecPlan::Mono,
            },
        };
        assert!(opt.evaluate(&d, Percentile::Avg).is_err());
    }

    #[test]
    fn search_returns_ranked_list() {
        let dev = samsung_a71();
        let reg = fake_registry();
        let lut = setup(&dev, &reg);
        let opt = Optimizer::new(&dev, &reg, &lut);
        let all = opt
            .search(Objective::MinLatency { stat: Percentile::Avg, epsilon: 0.05 },
                    &SearchSpace::family("mobilenet_v2_100"))
            .unwrap();
        assert!(all.len() > 10);
        for w in all.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }
}
